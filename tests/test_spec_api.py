"""SearchSpec -> plan -> stream pipeline: golden parity against hand-rolled
materialized references, JSON round-trips, streaming bounds, and the mode-2
composition pruning."""
import dataclasses
import json
import warnings

import pytest

from repro.calibration.fit import AnalyticEtaModel
from repro.core import (
    Astra,
    DeviceSweep,
    FixedPool,
    GpuConfig,
    HeteroCaps,
    HeteroPool,
    Limits,
    ObjectiveSpec,
    SearchSpec,
    Workload,
)
from repro.core.batch import BatchedCostSimulator
from repro.core.hetero import balanced_placements_for, iter_hetero_strategies
from repro.core.objectives import (
    DEFAULT_GRAMS_CO2_PER_KWH,
    CarbonObjective,
    LatencyObjective,
    MoneyObjective,
    ParetoObjective,
    ThroughputObjective,
    make_objective,
)
from repro.core.pareto import (
    CostedStrategy,
    carbon_cost,
    money_cost,
    optimal_pool,
    pick_within_budget,
    sort_strategies,
    strategy_watts,
)
from repro.core.rules import DEFAULT_RULES
from repro.core.search import FilterBank, generate_strategies

GB, SEQ = 128, 2048
POOL = HeteroPool(total_devices=32, type_caps=(("A800", 16), ("H100", 16)))


def _astra() -> Astra:
    return Astra(AnalyticEtaModel())


def _spec_mode1(llama7b, **limits) -> SearchSpec:
    return SearchSpec(
        arch=llama7b,
        pool=FixedPool("A800", 64),
        workload=Workload(GB, SEQ),
        limits=Limits(**limits) if limits else Limits(),
    )


def _assert_reports_equal(a, b, *, check_pool=True):
    assert a.mode == b.mode
    assert a.best == b.best
    assert [c.strategy for c in a.top] == [c.strategy for c in b.top]
    ca, cb = a.counts, b.counts
    assert (ca.generated, ca.divisible, ca.after_rules, ca.after_memory) == (
        cb.generated, cb.divisible, cb.after_rules, cb.after_memory
    )
    if check_pool:
        assert [c.strategy for c in a.pool] == [c.strategy for c in b.pool]


# ---------------------------------------------------------------------------
# golden parity: the streamed pipeline == a hand-rolled materialize+sort
# reference built from the primitives, for every pool shape
# ---------------------------------------------------------------------------

def test_mode1_pipeline_matches_materialized_reference(llama7b):
    report = _astra().search(_spec_mode1(llama7b, top_k=5))

    strategies, counts = generate_strategies(
        llama7b, [GpuConfig("A800", 64)], GB, SEQ
    )
    engine = BatchedCostSimulator(AnalyticEtaModel())
    sims = engine.simulate_batch(llama7b, strategies, global_batch=GB, seq=SEQ)
    costed = [
        CostedStrategy(strategy=s, sim=r, throughput=r.throughput_tokens,
                       money=money_cost(r, 1e9))
        for s, r in zip(strategies, sims)
    ]
    ranked = sort_strategies(costed)
    assert report.best == ranked[0].strategy
    assert [c.strategy for c in report.top] == [c.strategy for c in ranked[:5]]
    assert report.counts.generated == counts.generated
    assert report.counts.after_memory == counts.after_memory == report.evaluated


def test_mode2_pipeline_matches_materialized_reference(llama7b):
    """The spec pipeline over HeteroCaps equals filtering + simulating +
    Eq. 33-sorting the raw hetero stream by hand (the golden reference the
    removed legacy facade used to provide)."""
    report = _astra().search(SearchSpec(
        arch=llama7b, pool=HeteroCaps.of(POOL, prune_slack=None),
        workload=Workload(GB, SEQ),
    ))

    bank = FilterBank(llama7b, SEQ, DEFAULT_RULES)
    strategies = [
        s for s in iter_hetero_strategies(llama7b, POOL, GB, fast=True)
        if bank.rules_ok(s) and bank.memory_ok(s)
    ]
    engine = BatchedCostSimulator(AnalyticEtaModel())
    sims = engine.simulate_batch(llama7b, strategies, global_batch=GB, seq=SEQ)
    costed = [
        CostedStrategy(strategy=s, sim=r, throughput=r.throughput_tokens,
                       money=money_cost(r, 1e9))
        for s, r in zip(strategies, sims)
    ]
    ranked = sort_strategies(costed)
    assert report.best == ranked[0].strategy
    assert report.best is not None and report.best.hetero is not None
    assert [c.strategy for c in report.top] == [c.strategy for c in ranked[:5]]
    assert report.counts.after_memory == len(strategies) == report.evaluated


def test_mode3_pipeline_matches_materialized_reference(llama7b):
    budget = 120.0
    report = _astra().search(
        SearchSpec(
            arch=llama7b, pool=DeviceSweep(("A800", "H100"), 64),
            workload=Workload(GB, SEQ), objective=ObjectiveSpec.pareto(budget),
        )
    )
    gpus = [GpuConfig(d, n) for d in ("A800", "H100") for n in (2, 4, 8, 16, 32, 64)]
    strategies, _ = generate_strategies(llama7b, gpus, GB, SEQ)
    engine = BatchedCostSimulator(AnalyticEtaModel())
    sims = engine.simulate_batch(llama7b, strategies, global_batch=GB, seq=SEQ)
    costed = [
        CostedStrategy(strategy=s, sim=r, throughput=r.throughput_tokens,
                       money=money_cost(r, 1e9))
        for s, r in zip(strategies, sims)
    ]
    pool = optimal_pool(costed)
    assert [c.strategy for c in report.pool] == [c.strategy for c in pool]
    best = pick_within_budget(pool, budget)
    assert report.best == (best.strategy if best else None)


def test_scalar_and_batched_engines_agree_via_spec(llama7b):
    space = {
        "tensor_parallel": [2, 4],
        "pipeline_parallel": [2, 4],
        "micro_batch_size": [1, 2],
        "use_distributed_optimizer": [True],
        "recompute_granularity": ["none", "full"],
    }
    spec = dataclasses.replace(_spec_mode1(llama7b), space=space)
    r_fast = Astra(AnalyticEtaModel(), use_batched=True).search(spec)
    r_ref = Astra(AnalyticEtaModel(), use_batched=False).search(spec)
    assert r_fast.best == r_ref.best
    assert [c.strategy for c in r_fast.top] == [c.strategy for c in r_ref.top]
    assert r_fast.best_sim.step_time == pytest.approx(
        r_ref.best_sim.step_time, rel=1e-9
    )


# ---------------------------------------------------------------------------
# serialization
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make_pool", [
    lambda: FixedPool("A800", 64),
    lambda: HeteroCaps(32, (("A800", 16), ("H100", 16)), fast=True,
                       prune_slack=1.5),
    lambda: DeviceSweep(("A800", "H100"), 128, min_devices=4),
])
def test_spec_json_round_trip(llama7b, make_pool):
    spec = SearchSpec(
        arch=llama7b,
        pool=make_pool(),
        workload=Workload(512, 4096, train_tokens=2e9),
        objective=ObjectiveSpec.pareto(80.0),
        space={"tensor_parallel": [1, 2]},
        hetero_base={"use_flash_attn": True},
        limits=Limits(top_k=7, chunk_size=128, max_candidates=1000),
    )
    text = spec.to_json()
    json.loads(text)  # valid JSON
    assert SearchSpec.from_json(text) == spec


def test_spec_json_round_trip_search_identical(llama7b):
    spec = _spec_mode1(llama7b)
    r1 = _astra().search(spec)
    r2 = _astra().search(SearchSpec.from_json(spec.to_json()))
    _assert_reports_equal(r1, r2)


def test_spec_rejects_unknown_kinds(llama7b):
    with pytest.raises(ValueError):
        ObjectiveSpec("vibes")
    with pytest.raises(ValueError):
        ObjectiveSpec("throughput", slo_seconds=1.0)  # latency-only knob
    with pytest.raises(ValueError):
        ObjectiveSpec.latency(0.0)
    with pytest.raises(ValueError):
        ObjectiveSpec("money", grams_co2_per_kwh=400.0)  # carbon-only knob
    with pytest.raises(ValueError):
        ObjectiveSpec.carbon(grams_co2_per_kwh=-1.0)
    d = _spec_mode1(llama7b).to_dict()
    d["pool"]["kind"] = "quantum"
    with pytest.raises(ValueError):
        SearchSpec.from_dict(d)


# ---------------------------------------------------------------------------
# the legacy facades are gone (spec is the only entry point)
# ---------------------------------------------------------------------------

def test_legacy_facades_removed():
    astra = _astra()
    for name in ("search_homogeneous", "search_heterogeneous", "search_cost"):
        assert not hasattr(astra, name)


def test_spec_entry_point_does_not_warn(llama7b):
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        _astra().search(_spec_mode1(llama7b))
    assert not [w for w in caught if issubclass(w.category, FutureWarning)]


# ---------------------------------------------------------------------------
# streaming bounds
# ---------------------------------------------------------------------------

def test_mode2_streams_without_materializing(llama7b, monkeypatch):
    """Mode 2 must hand the evaluator chunks bounded by chunk_size — never
    the whole candidate list."""
    chunk_size = 16
    seen = []
    orig = BatchedCostSimulator.simulate_batch

    def spy(self, arch, strategies, **kw):
        seen.append(len(strategies))
        return orig(self, arch, strategies, **kw)

    monkeypatch.setattr(BatchedCostSimulator, "simulate_batch", spy)
    report = _astra().search(
        SearchSpec(
            arch=llama7b, pool=HeteroCaps.of(POOL),
            workload=Workload(GB, SEQ), limits=Limits(chunk_size=chunk_size),
        )
    )
    assert report.best is not None
    assert seen and max(seen) <= chunk_size
    assert sum(seen) == report.evaluated == report.counts.after_memory


def test_max_candidates_limit_caps_evaluation(llama7b):
    capped = _astra().search(_spec_mode1(llama7b, max_candidates=100))
    assert capped.evaluated == 100
    assert capped.counts.after_memory == 100  # funnel reflects the cutoff


# ---------------------------------------------------------------------------
# objectives
# ---------------------------------------------------------------------------

def test_make_objective_dispatch():
    assert isinstance(make_objective(ObjectiveSpec.throughput()), ThroughputObjective)
    assert isinstance(make_objective(ObjectiveSpec.money(5.0)), MoneyObjective)
    assert isinstance(make_objective(ObjectiveSpec.pareto(5.0)), ParetoObjective)
    lat = make_objective(ObjectiveSpec.latency(2.5))
    assert isinstance(lat, LatencyObjective) and lat.slo_seconds == 2.5
    car = make_objective(ObjectiveSpec.carbon(12.0, 300.0), train_tokens=2e9)
    assert isinstance(car, CarbonObjective)
    assert car.budget_kg == 12.0 and car.grams_co2_per_kwh == 300.0
    assert car.train_tokens == 2e9
    # default grid intensity applies when the spec leaves it unset
    assert make_objective(ObjectiveSpec.carbon()).grams_co2_per_kwh \
        == DEFAULT_GRAMS_CO2_PER_KWH


def test_latency_objective_picks_cheapest_within_slo(llama7b):
    thr = _astra().search(_spec_mode1(llama7b))
    # an SLO looser than the fastest plan's step time is satisfiable
    slo = thr.top[0].sim.step_time * 2.0
    # the objective travels the wire like any other spec field
    spec = SearchSpec.from_json(dataclasses.replace(
        _spec_mode1(llama7b), objective=ObjectiveSpec.latency(slo)
    ).to_json())
    assert spec.objective.slo_seconds == slo
    rep = _astra().search(spec)
    assert rep.best is not None
    assert rep.best_sim.step_time <= slo
    # cheapest SLO-satisfier: no throughput-top candidate meeting the SLO
    # is cheaper than the latency pick
    pick_money = money_cost(rep.best_sim, 1e9)
    for c in thr.top:
        if c.sim.step_time <= slo:
            assert pick_money <= c.money + 1e-12


def test_latency_objective_infeasible_slo_returns_none(llama7b):
    rep = _astra().search(dataclasses.replace(
        _spec_mode1(llama7b), objective=ObjectiveSpec.latency(1e-9)
    ))
    assert rep.best is None and rep.best_sim is None


def test_money_objective_picks_cheapest(llama7b):
    thr = _astra().search(_spec_mode1(llama7b))
    cheap = _astra().search(
        dataclasses.replace(_spec_mode1(llama7b), objective=ObjectiveSpec.money())
    )
    best_thr = thr.top[0]
    best_cheap = cheap.top[0]
    assert best_cheap.money <= best_thr.money
    # money ranking is ascending in cost
    monies = [c.money for c in cheap.top]
    assert monies == sorted(monies)
    # cheapest pick must sit on the Pareto pool
    assert cheap.pool
    assert min(c.money for c in cheap.pool) == pytest.approx(best_cheap.money)


def test_carbon_objective_picks_lowest_emissions(llama7b):
    tokens = 1e9
    thr = _astra().search(_spec_mode1(llama7b))
    green = _astra().search(dataclasses.replace(
        _spec_mode1(llama7b), objective=ObjectiveSpec.carbon()
    ))
    kg = lambda c: carbon_cost(  # noqa: E731
        c.strategy, c.sim, tokens, DEFAULT_GRAMS_CO2_PER_KWH
    )
    assert green.best is not None
    # carbon ranking is ascending in emissions
    kgs = [kg(c) for c in green.top]
    assert kgs == sorted(kgs)
    # the pick emits no more than the fastest plan
    assert kgs[0] <= kg(thr.top[0]) + 1e-12
    # fixed pool, one device type: emissions scale with device-hours, and
    # they are strictly positive and finite
    assert 0 < kgs[0] < float("inf")


def test_carbon_objective_budget_and_infeasible(llama7b):
    green = _astra().search(dataclasses.replace(
        _spec_mode1(llama7b), objective=ObjectiveSpec.carbon()
    ))
    best_kg = carbon_cost(
        green.top[0].strategy, green.top[0].sim, 1e9,
        DEFAULT_GRAMS_CO2_PER_KWH,
    )
    # a budget just above the best pick keeps it
    ok = _astra().search(dataclasses.replace(
        _spec_mode1(llama7b),
        objective=ObjectiveSpec.carbon(budget_kg=best_kg * 1.01),
    ))
    assert ok.best == green.best
    # an impossible budget returns no plan instead of a wrong one
    none = _astra().search(dataclasses.replace(
        _spec_mode1(llama7b),
        objective=ObjectiveSpec.carbon(budget_kg=best_kg * 1e-6),
    ))
    assert none.best is None and none.best_sim is None


def test_carbon_objective_travels_the_wire(llama7b):
    spec = dataclasses.replace(
        _spec_mode1(llama7b),
        objective=ObjectiveSpec.carbon(budget_kg=50.0, grams_co2_per_kwh=320.0),
    )
    round_tripped = SearchSpec.from_json(spec.to_json())
    assert round_tripped == spec
    assert round_tripped.objective.kind == "carbon"
    assert round_tripped.objective.budget == 50.0
    assert round_tripped.objective.grams_co2_per_kwh == 320.0
    # the carbon knobs separate cache identities; leaving them at their
    # defaults does not perturb existing keys
    base = _spec_mode1(llama7b)
    assert spec.cache_key() != base.cache_key()
    assert dataclasses.replace(base).cache_key() == base.cache_key()


def test_strategy_watts_homogeneous_and_hetero(llama7b):
    from repro.core.params import HeteroPlacement, ParallelStrategy
    from repro.hw.catalog import get_device

    homo = ParallelStrategy(device="A800", num_devices=16)
    assert strategy_watts(homo) == 16 * get_device("A800").tdp_watts
    # hetero: 2 A800 stages + 2 H100 stages, 4 devices per stage
    het = ParallelStrategy(
        device="A800", num_devices=16, pipeline_parallel=4, tensor_parallel=2,
        hetero=HeteroPlacement(
            devices=("A800", "H100"), stages_per_type=(2, 2),
            layers_per_stage=(16, 16),
        ),
    )
    expect = (2 * 4) * get_device("A800").tdp_watts \
        + (2 * 4) * get_device("H100").tdp_watts
    assert strategy_watts(het) == expect


# ---------------------------------------------------------------------------
# mode-2 composition pruning
# ---------------------------------------------------------------------------

def test_pruned_placements_are_subset_and_keep_best(llama7b):
    astra_full = _astra()
    astra_pruned = _astra()
    w = Workload(GB, SEQ)
    full = astra_full.search(SearchSpec(
        arch=llama7b, pool=HeteroCaps.of(POOL, prune_slack=None), workload=w))
    pruned = astra_pruned.search(SearchSpec(
        arch=llama7b, pool=HeteroCaps.of(POOL, prune_slack=1.5), workload=w))
    assert pruned.counts.generated < full.counts.generated
    assert pruned.best == full.best
    assert pruned.best_sim.throughput_tokens == pytest.approx(
        full.best_sim.throughput_tokens, rel=1e-9
    )


def test_balanced_placements_cell_cache_prunes_dominated(llama7b):
    full = balanced_placements_for(
        llama7b, POOL, pipeline_parallel=4, devices_per_stage=4,
        prune_slack=None,
    )
    pruned = balanced_placements_for(
        llama7b, POOL, pipeline_parallel=4, devices_per_stage=4,
        prune_slack=1.5,
    )
    assert set(pruned) <= set(full)
    assert 0 < len(pruned) <= len(full)
    # every placement still spans the full layer budget
    for pl in pruned:
        assert pl.total_layers == llama7b.num_layers


def test_hetero_funnel_counts_stay_honest_under_pruning(llama7b):
    """generated must equal what the generator actually emitted."""
    emitted = sum(
        1 for _ in iter_hetero_strategies(
            llama7b, POOL, GB, fast=True, prune_slack=1.5
        )
    )
    report = _astra().search(SearchSpec(
        arch=llama7b, pool=HeteroCaps.of(POOL, prune_slack=1.5),
        workload=Workload(GB, SEQ),
    ))
    assert report.counts.generated == emitted
    c = report.counts
    assert c.generated == c.divisible >= c.after_rules >= c.after_memory > 0


# ---------------------------------------------------------------------------
# filter bank
# ---------------------------------------------------------------------------

def test_filter_bank_memoizes_without_changing_verdicts(llama7b):
    from repro.core.memory import MemoryFilter
    from repro.core.rules import DEFAULT_RULES, RuleFilter
    from repro.core.search import iter_raw_strategies, strategy_env

    bank = FilterBank(llama7b, SEQ, DEFAULT_RULES)
    rule_ref = RuleFilter(DEFAULT_RULES)
    mem_ref = MemoryFilter(seq=SEQ)
    checked = 0
    for gpu in (GpuConfig("A800", 32), GpuConfig("A800", 64)):
        for s in iter_raw_strategies(llama7b, gpu, GB):
            if not s.is_divisible(llama7b, GB):
                continue
            assert bank.rules_ok(s) == rule_ref.is_valid(strategy_env(llama7b, s))
            assert bank.memory_ok(s) == mem_ref.is_valid(llama7b, s)
            checked += 1
    assert checked > 500
    # memoization actually deduplicates: far fewer distinct keys than checks
    assert len(bank._mem_memo) < checked
    assert len(bank._rule_memo) < checked
