"""Calibration guard for the mode-2 composition-pruning slack.

``HeteroCaps.prune_slack`` bounds the water-filling minimax by its
fractional FLOPs-proxy relaxation (see ``balanced_placements_for``): a
composition is skipped when its lower bound exceeds ``slack`` x the best
achieved discrete minimax. This test measures, on the seed fixtures plus a
bigger 48-device asymmetric pool, the tightest slack that still keeps the
full-sweep optimum in the pruned candidate stream, and asserts the default
preserves the optimum — recording the measured margin in the assertion
message so a future tightening toward 1.0 has data to point at.

Calibration history: the original 1.5 default was uncalibrated; the grid
measures the tightest preserving slack at 1.0 on every fixture (seed pools,
a 64-device symmetric pool and the 48-device pool below), so the default
was lowered to 1.2 — still a 0.2 margin over everything measured.
"""
from repro.calibration.fit import AnalyticEtaModel
from repro.core import Astra, HeteroCaps, SearchSpec, Workload
from repro.core.hetero import HeteroPool, iter_hetero_strategies

DEFAULT_SLACK = HeteroCaps.prune_slack  # the dataclass default under test
SLACK_GRID = (1.0, 1.05, 1.1, 1.15, 1.2, 1.3, 1.4, 1.5)


def _cases(llama7b, tiny_dense):
    return [
        (
            llama7b,
            HeteroPool(total_devices=32,
                       type_caps=(("A800", 16), ("H100", 16))),
            Workload(128, 2048),
        ),
        (
            llama7b,
            HeteroPool(total_devices=24,
                       type_caps=(("A800", 16), ("H100", 8))),  # asymmetric
            Workload(128, 2048),
        ),
        (
            tiny_dense,
            HeteroPool(total_devices=8,
                       type_caps=(("A800", 4), ("H100", 4))),
            Workload(32, 512),
        ),
        (
            llama7b,
            # bigger pool (the ROADMAP's re-measure ask): more composition
            # cells, asymmetric caps, so the FLOPs-proxy bound is stressed
            # harder than on the seed fixtures
            HeteroPool(total_devices=48,
                       type_caps=(("A800", 32), ("H100", 16))),
            Workload(128, 2048),
        ),
    ]


def _strip_placement_key(s):
    """Identity of a candidate for stream-containment checks."""
    return (
        s.tensor_parallel, s.pipeline_parallel, s.micro_batch_size,
        s.num_devices, s.hetero,
    )


def test_default_prune_slack_preserves_optimum_with_measured_margin(
    llama7b, tiny_dense
):
    assert DEFAULT_SLACK == 1.2  # the calibrated default (was 1.5; every
    # fixture measures tightest-preserving slack 1.0 — see module docstring)
    measured = []
    for arch, pool, w in _cases(llama7b, tiny_dense):
        astra = Astra(AnalyticEtaModel())
        full = astra.search(SearchSpec(
            arch=arch, pool=HeteroCaps.of(pool, prune_slack=None), workload=w,
        ))
        assert full.best is not None and full.best.hetero is not None
        best_key = _strip_placement_key(full.best)

        # the tightest grid slack whose pruned stream still *generates* the
        # full-sweep optimum (generation-level containment is the exact
        # condition for the search to preserve it: filters and ranking are
        # slack-independent)
        tightest = None
        for slack in SLACK_GRID:
            stream = iter_hetero_strategies(
                arch, pool, w.global_batch, fast=True, prune_slack=slack,
            )
            if any(_strip_placement_key(s) == best_key for s in stream):
                tightest = slack
                break
        margin = DEFAULT_SLACK - (tightest if tightest is not None else
                                  float("inf"))
        measured.append((arch.name, pool.type_caps, tightest, margin))

        # and the end-to-end search at the default really keeps the optimum
        pruned = Astra(AnalyticEtaModel()).search(SearchSpec(
            arch=arch, pool=HeteroCaps.of(pool, prune_slack=DEFAULT_SLACK),
            workload=w,
        ))
        assert pruned.best == full.best and pruned.counts.generated <= \
            full.counts.generated, (
                f"prune_slack={DEFAULT_SLACK} lost the optimum on "
                f"{arch.name} over {pool.type_caps}: tightest preserving "
                f"slack measured on the grid is {tightest} "
                f"(margin {margin:+.2f} before the default fails)"
            )

    # the default must clear every fixture, with the measured calibration
    # recorded for the ROADMAP's tighten-toward-1.0 follow-up
    assert all(t is not None and t <= DEFAULT_SLACK
               for _, _, t, _ in measured), (
        "default prune_slack no longer preserves the optimum; measured "
        f"tightest-preserving slacks per fixture: {measured}"
    )


def test_prune_slack_none_and_default_funnels_nest(llama7b):
    """Sanity on the calibration premise: the pruned stream is a subset of
    the exhaustive one for every fixture cell."""
    pool = HeteroPool(total_devices=32, type_caps=(("A800", 16), ("H100", 16)))
    full = {
        _strip_placement_key(s)
        for s in iter_hetero_strategies(llama7b, pool, 128, fast=True,
                                        prune_slack=None)
    }
    pruned = {
        _strip_placement_key(s)
        for s in iter_hetero_strategies(llama7b, pool, 128, fast=True,
                                        prune_slack=DEFAULT_SLACK)
    }
    assert pruned <= full
    assert 0 < len(pruned) <= len(full)
