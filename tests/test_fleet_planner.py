"""Fleet capacity planner: spec identity, grid reuse, solver invariants,
and the /v1/plan + /metrics endpoints.

The tier-1 acceptance checks live here: a 2-pool / 3-job fleet yields a
plan that (a) never exceeds pool capacity, (b) aggregates at least the
naive single-pool-per-job baseline, (c) round-trips through the wire
format bit for bit, and (d) re-plans from a warm grid with *zero* engine
re-searches (probed with CountingAstra call counters). Solver unit tests
run on synthetic options against an independent brute-force enumeration —
no searches at all."""
import dataclasses
import itertools
import json
import urllib.request

import pytest

from harness_service import CountingAstra, http_service, request as _request
from repro.core.spec import Limits
from repro.fleet import (
    FleetObjective,
    FleetPlan,
    FleetSpec,
    FleetWorkload,
    GpuPool,
    Option,
    grid_cells,
    search_grid,
)
from repro.fleet import assign as fassign
from repro.serve.search_service import AuthQuota, SearchService, TokenInfo

SEQ = 512
SMALL_SPACE = {
    "tensor_parallel": [1, 2, 4],
    "pipeline_parallel": [1, 2],
    "micro_batch_size": [1, 2],
    "use_distributed_optimizer": [False, True],
    "recompute_granularity": ["none", "full"],
}


def _fleet(arch, **kw) -> FleetSpec:
    def wl(name, gb, **wkw):
        return FleetWorkload(name, arch, gb, SEQ, space=SMALL_SPACE, **wkw)

    return FleetSpec(
        pools=(GpuPool("a800-pool", "A800", 8),
               GpuPool("h100-pool", "H100", 4, price_per_hour=3.50)),
        workloads=(wl("job-a", 32), wl("job-b", 64, priority=2),
                   wl("job-c", 16)),
        **kw,
    )


@pytest.fixture(scope="module")
def planned(request):
    """One cold plan through a real service — shared by the read-only
    acceptance assertions (the expensive part runs once)."""
    arch = request.getfixturevalue("tiny_dense")
    engine = CountingAstra()
    service = SearchService(engine)
    fleet = _fleet(arch)
    key, text, cached = service.plan_json(fleet.to_json())
    assert cached is False
    return service, engine, fleet, key, text


# ---------------------------------------------------------------------------
# FleetSpec wire + identity
# ---------------------------------------------------------------------------

def test_fleet_spec_roundtrip_bitexact(tiny_dense):
    fleet = _fleet(tiny_dense, objective=FleetObjective.carbon(50.0))
    text = fleet.to_json()
    assert FleetSpec.from_json(text).to_json() == text


def test_cache_key_invariant_under_permutation(tiny_dense):
    fleet = _fleet(tiny_dense)
    shuffled = dataclasses.replace(
        fleet, pools=tuple(reversed(fleet.pools)),
        workloads=tuple(reversed(fleet.workloads)),
    )
    assert shuffled.cache_key() == fleet.cache_key()


def test_cache_key_sees_content_not_execution_limits(tiny_dense):
    fleet = _fleet(tiny_dense)
    assert dataclasses.replace(
        fleet, limits=Limits(workers=4)
    ).cache_key() == fleet.cache_key()
    bigger = dataclasses.replace(
        fleet, pools=(dataclasses.replace(fleet.pools[0], capacity=16),
                      fleet.pools[1]),
    )
    assert bigger.cache_key() != fleet.cache_key()


def test_fleet_spec_validation(tiny_dense):
    wl = FleetWorkload("w", tiny_dense, 32, SEQ)
    pool = GpuPool("p", "A800", 8)
    with pytest.raises(ValueError, match="duplicate pool"):
        FleetSpec(pools=(pool, GpuPool("p", "H100", 4)), workloads=(wl,))
    with pytest.raises(ValueError, match="duplicate workload"):
        FleetSpec(pools=(pool,), workloads=(wl, wl))
    with pytest.raises(ValueError, match="at least one workload"):
        FleetSpec(pools=(pool,), workloads=())
    with pytest.raises(ValueError, match="capacity"):
        GpuPool("p", "A800", 0)
    with pytest.raises(ValueError, match="unknown fleet objective"):
        FleetObjective("cheapest")
    with pytest.raises(ValueError, match="carbon_budget_kg only applies"):
        FleetObjective("throughput", carbon_budget_kg=10.0)


# ---------------------------------------------------------------------------
# the planned fleet: acceptance criteria (a)-(d)
# ---------------------------------------------------------------------------

def test_plan_respects_capacity(planned):
    _, _, fleet, _, text = planned
    plan = FleetPlan.from_json(text)
    assert len(plan.assignments) == 3 and not plan.unassigned
    used = {p.name: 0 for p in fleet.pools}
    for a in plan.assignments:
        used[a.pool] += a.devices
    for pu in plan.pools:
        assert pu.used == used[pu.pool]
        assert 0 <= pu.used <= pu.capacity
        assert pu.leftover == pu.capacity - pu.used


def test_plan_wire_roundtrip_bitexact(planned):
    _, _, _, _, text = planned
    assert FleetPlan.from_json(text).to_json() == text


def test_plan_beats_naive_baseline(planned):
    service, _, fleet, _, text = planned
    plan = FleetPlan.from_json(text)
    canon = fleet.canonical()
    cells, _, _ = search_grid(service, fleet)  # warm replay of the grid
    options, _ = fassign.build_options(canon, cells)
    naive = fassign._naive(canon, options, canon.objective)
    naive_score = fassign._score(canon, options, canon.objective, naive)
    _, thr, dph, _ = fassign._totals(canon, options, naive)
    assert plan.total_throughput > 0
    assert (plan.throughput_per_dollar
            >= fassign._value(thr, dph, canon.objective))
    # the winning candidate scores at least the naive candidate
    got = (sum(fleet.workloads[i].priority for i in range(3)),
           plan.throughput_per_dollar)
    assert got >= naive_score[:2]


def test_warm_plan_cached_and_byte_identical(planned):
    service, engine, fleet, key, text = planned
    calls = engine.calls
    key2, text2, cached = service.plan_json(fleet.to_json())
    assert (key2, cached) == (key, True)
    assert text2 == text
    assert engine.calls == calls


def test_permuted_fleet_hits_same_plan(planned):
    service, engine, fleet, key, text = planned
    calls = engine.calls
    shuffled = dataclasses.replace(
        fleet, pools=tuple(reversed(fleet.pools)),
        workloads=tuple(reversed(fleet.workloads)),
    )
    key2, text2, cached = service.plan_json(shuffled.to_json())
    assert (key2, text2, cached) == (key, text, True)
    assert engine.calls == calls


def test_replan_from_warm_grid_runs_zero_searches(planned):
    """Acceptance (d): evict the plan, keep the grid — the re-plan must be
    byte-identical to the cold plan without a single engine search."""
    service, engine, fleet, key, text = planned
    service.store.delete(key)
    calls = engine.calls
    warm_before = service.stats.grid_warm_hits
    key2, text2, cached = service.plan_json(fleet.to_json())
    assert (key2, cached) == (key, False)
    assert text2 == text  # warm-grid plan == cold plan, bit for bit
    assert engine.calls == calls  # zero re-searches
    assert (service.stats.grid_warm_hits - warm_before
            == len(grid_cells(fleet)))


def test_incremental_replan_searches_only_new_cells(planned):
    service, engine, fleet, _, _ = planned
    grown = dataclasses.replace(
        fleet, workloads=fleet.workloads + (
            FleetWorkload("job-d", fleet.workloads[0].arch, 48, SEQ,
                          space=SMALL_SPACE),
        ),
    )
    calls = engine.calls
    _, text, cached = service.plan_json(grown.to_json())
    assert cached is False
    assert engine.calls == calls + len(fleet.pools)  # only job-d's cells
    assert len(FleetPlan.from_json(text).assignments) == 4


def test_plan_counts_merge_distinct_cells(planned):
    service, _, fleet, _, text = planned
    plan = FleetPlan.from_json(text)
    _, _, merged = search_grid(service, fleet)
    assert plan.counts.to_dict() == merged.to_dict()
    assert plan.counts.generated > 0


def test_deadline_filters_to_unassigned(planned):
    """An impossible deadline drops every placement — the job lands in
    ``unassigned`` with the deadline reason. Cells stay warm (the deadline
    is an assignment parameter, not a search parameter)."""
    service, engine, fleet, _, _ = planned
    calls = engine.calls
    doomed = dataclasses.replace(
        fleet, workloads=tuple(
            dataclasses.replace(w, deadline_hours=1e-9)
            if w.name == "job-c" else w
            for w in fleet.workloads
        ),
    )
    _, text, _ = service.plan_json(doomed.to_json())
    plan = FleetPlan.from_json(text)
    assert engine.calls == calls
    assert [u["workload"] for u in plan.unassigned] == ["job-c"]
    assert plan.unassigned[0]["reason"] == \
        "deadline_hours filters every placement"
    assert len(plan.assignments) == 2


# ---------------------------------------------------------------------------
# solver invariants on synthetic options (no searches)
# ---------------------------------------------------------------------------

def _synthetic(arch, pools, names, priorities=None):
    priorities = priorities or [1] * len(names)
    return FleetSpec(
        pools=tuple(GpuPool(n, "A800", cap) for n, cap in pools),
        workloads=tuple(
            FleetWorkload(n, arch, 32, SEQ, priority=p)
            for n, p in zip(names, priorities)
        ),
    ).canonical()


def _opt(w, pool, devices, thr, dph=1.0, carbon=0.0):
    return Option(workload=w, pool=pool, devices=devices, choice=None,
                  throughput=thr, dollars_per_hour=dph, money=0.0,
                  train_hours=1.0, carbon_kg=carbon)


def _brute_force(canon, options, objective):
    """Independent optimum: enumerate every (option|skip) combination."""
    best = None
    choices = [[None] + list(range(len(options[w.name])))
               for w in canon.workloads]
    for assign in itertools.product(*choices):
        cap = {p.name: p.capacity for p in canon.pools}
        ok = True
        for i, j in enumerate(assign):
            if j is None:
                continue
            o = options[canon.workloads[i].name][j]
            cap[o.pool] -= o.devices
            if cap[o.pool] < 0:
                ok = False
                break
        if not ok:
            continue
        score = fassign._score(canon, options, objective, list(assign))
        if score is None:
            continue
        sig = fassign._signature(assign)
        if (best is None or score > best[0]
                or (score == best[0] and sig < best[1])):
            best = (score, sig)
    return best


def test_exhaustive_matches_brute_force(tiny_dense):
    import random

    rng = random.Random(7)
    for trial in range(25):
        n_pools = rng.randint(1, 3)
        pools = [(f"p{k}", rng.randint(2, 6)) for k in range(n_pools)]
        names = [f"w{k}" for k in range(3)]
        prios = [rng.randint(1, 3) for _ in names]
        canon = _synthetic(tiny_dense, pools, names, prios)
        objective = rng.choice([FleetObjective.throughput(),
                                FleetObjective.throughput_per_dollar()])
        canon = dataclasses.replace(canon, objective=objective)
        options = {}
        for w in names:
            opts = [
                _opt(w, f"p{rng.randrange(n_pools)}", rng.randint(1, 4),
                     thr=rng.randint(10, 100) * 1.0,
                     dph=rng.randint(1, 8) * 1.0)
                for _ in range(rng.randint(0, 3))
            ]
            opts.sort(key=lambda o: (-o.throughput, o.dollars_per_hour,
                                     o.pool, o.devices))
            options[w] = opts
        exh = fassign._exhaustive(canon, options, objective)
        exh_score = fassign._score(canon, options, objective, exh)
        ref = _brute_force(canon, options, objective)
        assert exh_score == ref[0], f"trial {trial}"
        assert fassign._signature(exh) == ref[1], f"trial {trial}"
        for solver in (fassign._greedy, fassign._naive):
            s = fassign._score(canon, options, objective,
                               solver(canon, options, objective))
            assert s is not None and s <= exh_score, f"trial {trial}"


def test_greedy_priority_wins_scarce_capacity(tiny_dense):
    canon = _synthetic(tiny_dense, [("p0", 2)], ["hi", "lo"], [5, 1])
    options = {"hi": [_opt("hi", "p0", 2, thr=10.0)],
               "lo": [_opt("lo", "p0", 2, thr=100.0)]}
    for solver in (fassign._greedy, fassign._exhaustive):
        assign = solver(canon, options, canon.objective)
        picked = {canon.workloads[i].name
                  for i, j in enumerate(assign) if j is not None}
        assert picked == {"hi"}, solver.__name__


def test_greedy_regret_places_inflexible_job_first(tiny_dense):
    """The single-option job (infinite regret) claims its only slot before
    the flexible job eats it — greedy finds the 2-job packing."""
    canon = _synthetic(tiny_dense, [("p0", 2), ("p1", 2)], ["flex", "stuck"])
    options = {
        "flex": [_opt("flex", "p0", 2, thr=100.0),
                 _opt("flex", "p1", 2, thr=90.0)],
        "stuck": [_opt("stuck", "p0", 2, thr=50.0)],
    }
    objective = FleetObjective.throughput()
    canon = dataclasses.replace(canon, objective=objective)
    assign = fassign._greedy(canon, options, objective)
    _, thr, _, _ = fassign._totals(canon, options, assign)
    assert thr == 140.0  # stuck->p0, flex->p1; not flex->p0 + stuck dropped


def test_carbon_budget_is_a_hard_constraint(tiny_dense):
    objective = FleetObjective.carbon(10.0)
    canon = dataclasses.replace(
        _synthetic(tiny_dense, [("p0", 8)], ["a", "b", "c"]),
        objective=objective,
    )
    options = {
        "a": [_opt("a", "p0", 2, thr=100.0, carbon=6.0)],
        "b": [_opt("b", "p0", 2, thr=90.0, carbon=6.0)],
        "c": [_opt("c", "p0", 2, thr=10.0, carbon=3.0)],
    }
    over = fassign._score(canon, options, objective, [0, 0, 0])
    assert over is None  # 15 kg > 10 kg budget: infeasible, never ships
    for solver in (fassign._exhaustive, fassign._greedy, fassign._naive):
        assign = solver(canon, options, objective)
        _, _, _, carbon = fassign._totals(canon, options, assign)
        assert carbon <= 10.0, solver.__name__


def test_solve_falls_back_to_greedy_above_exhaustive_limit(planned):
    service, _, fleet, _, text = planned
    cells, _, counts = search_grid(service, fleet)
    plan = fassign.solve(fleet, cells, counts, exhaustive_limit=1)
    assert plan.solver in ("greedy", "naive")
    exact = FleetPlan.from_json(text)
    assert plan.total_throughput <= exact.total_throughput or \
        plan.throughput_per_dollar <= exact.throughput_per_dollar


# ---------------------------------------------------------------------------
# HTTP: POST /v1/plan + GET /metrics
# ---------------------------------------------------------------------------

def _small_fleet(arch):
    return FleetSpec(
        pools=(GpuPool("a800-pool", "A800", 4),),
        workloads=(FleetWorkload("solo", arch, 16, SEQ, space=SMALL_SPACE),),
    )


def _get_text(url: str, token=None) -> tuple[int, str, str]:
    headers = {"Authorization": f"Bearer {token}"} if token else {}
    req = urllib.request.Request(url, headers=headers)
    with urllib.request.urlopen(req) as resp:
        return (resp.status, resp.read().decode(),
                resp.headers.get("Content-Type", ""))


def test_http_plan_and_metrics(tiny_dense):
    engine = CountingAstra()
    service = SearchService(engine)
    auth = AuthQuota([TokenInfo("tok-a", "alice")])
    fleet_json = _small_fleet(tiny_dense).to_json()
    with http_service(service, auth=auth) as base:
        status, body = _request(f"{base}/v1/plan",
                                fleet_json.encode(), token="tok-a")
        assert status == 200 and body["status"] == "ready"
        assert body["cached"] is False
        plan = FleetPlan.from_dict(body["plan"])
        assert [a.workload for a in plan.assignments] == ["solo"]

        status2, body2 = _request(f"{base}/v1/plan",
                                  fleet_json.encode(), token="tok-a")
        assert status2 == 200 and body2["cached"] is True
        assert body2["plan"] == body["plan"]
        assert body2["key"] == body["key"]

        status3, body3 = _request(f"{base}/v1/plan", b"{\"version\": 1}",
                                  token="tok-a")
        assert status3 == 400 and "bad fleet spec" in body3["error"]

        status4, _ = _request(f"{base}/v1/plan", fleet_json.encode())
        assert status4 == 401  # no token

        code, text, ctype = _get_text(f"{base}/metrics", token="tok-a")
        assert code == 200
        assert ctype.startswith("text/plain") and "version=0.0.4" in ctype
        lines = text.splitlines()
        assert "# TYPE astra_plans_total counter" in lines
        assert "astra_plans_total 1" in lines
        assert "astra_grid_cells_total 1" in lines
        assert "astra_grid_warm_hits_total 0" in lines
        assert any(ln.startswith("astra_misses_total ") for ln in lines)
        assert "# TYPE astra_hit_rate gauge" in lines
        assert 'astra_token_requests_total{identity="alice"}' in text
        assert "astra_unauthorized_total 1" in lines
    assert engine.calls == 1


def test_metrics_text_is_float_safe(tiny_dense):
    service = SearchService(CountingAstra())
    from repro.serve.search_service import metrics_text

    text = metrics_text(service)
    for ln in text.splitlines():
        if ln.startswith("#"):
            continue
        name, value = ln.rsplit(" ", 1)
        float(value)  # every sample parses as a number
    assert text.endswith("\n")
