"""Checkpoint manager (atomic/async/keep-k/elastic) + data pipeline resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import MarkovCorpus, SyntheticPipeline
from repro.train.optimizer import adamw_init


def _state():
    params = {"layer": {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones(3)}}
    return {"params": params, "opt": adamw_init(params)}


def test_roundtrip_exact(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = _state()
    mgr.save(10, state, metadata={"data_step": 7}, blocking=True)
    restored, meta = mgr.restore(state)
    assert meta["step"] == 10 and meta["data_step"] == 7
    for a, b in zip(jax.tree_util.tree_leaves(state), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_then_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state())
    mgr.wait()
    assert mgr.latest_step() == 1


def test_keep_k_garbage_collection(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state(), blocking=True)
    assert mgr.steps() == [3, 4]


def test_no_tmp_dirs_left_behind(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, _state(), blocking=True)
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_restore_latest_and_specific(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    s = _state()
    mgr.save(1, s, blocking=True)
    s2 = jax.tree_util.tree_map(lambda x: x + 1, s)
    mgr.save(2, s2, blocking=True)
    r2, _ = mgr.restore(s)
    np.testing.assert_array_equal(
        np.asarray(r2["params"]["layer"]["w"]), np.asarray(s2["params"]["layer"]["w"])
    )
    r1, _ = mgr.restore(s, step=1)
    np.testing.assert_array_equal(
        np.asarray(r1["params"]["layer"]["w"]), np.asarray(s["params"]["layer"]["w"])
    )


def test_elastic_restore_with_shardings(tmp_path):
    """Restore placing leaves onto explicit (single-device) shardings —
    the elastic-restart path; on a pod the same call re-shards to a new mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1, 1), ("data", "model"))
    mgr = CheckpointManager(str(tmp_path))
    s = _state()
    mgr.save(3, s, blocking=True)
    sh = jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), s)
    restored, _ = mgr.restore(s, shardings=sh)
    leaf = restored["params"]["layer"]["w"]
    assert isinstance(leaf, jax.Array) and leaf.sharding == NamedSharding(mesh, P())


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        CheckpointManager(str(tmp_path)).restore({})


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------

def test_pipeline_deterministic_per_step():
    c = MarkovCorpus(64, seed=1)
    p1 = SyntheticPipeline(corpus=c, global_batch=4, seq_len=16)
    p2 = SyntheticPipeline(corpus=c, global_batch=4, seq_len=16)
    np.testing.assert_array_equal(p1.next_batch()["tokens"], p2.next_batch()["tokens"])
    np.testing.assert_array_equal(p1.next_batch()["tokens"], p2.next_batch()["tokens"])


def test_pipeline_resume_from_state_dict():
    c = MarkovCorpus(64, seed=1)
    p = SyntheticPipeline(corpus=c, global_batch=4, seq_len=16)
    p.next_batch()
    p.next_batch()
    saved = p.state_dict()
    b3 = p.next_batch()
    q = SyntheticPipeline(corpus=c, global_batch=4, seq_len=16)
    q.load_state_dict(saved)
    np.testing.assert_array_equal(q.next_batch()["tokens"], b3["tokens"])


def test_pipeline_shards_disjoint_deterministic():
    c = MarkovCorpus(64, seed=1)
    shard0 = SyntheticPipeline(corpus=c, global_batch=8, seq_len=16,
                               shard_index=0, num_shards=2)
    shard1 = SyntheticPipeline(corpus=c, global_batch=8, seq_len=16,
                               shard_index=1, num_shards=2)
    b0, b1 = shard0.next_batch()["tokens"], shard1.next_batch()["tokens"]
    assert b0.shape == (4, 16) and b1.shape == (4, 16)
    assert not np.array_equal(b0, b1)


def test_markov_entropy_below_uniform():
    c = MarkovCorpus(64, seed=0, temperature=0.3)
    assert c.entropy_rate() < np.log(64) * 0.85
