"""Search-space generator, memory filter, cost model, Eq. 22, Pareto pool."""
import dataclasses
import math

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.calibration.fit import AnalyticEtaModel
from repro.core import (
    Astra,
    CostSimulator,
    FixedPool,
    GpuConfig,
    HeteroCaps,
    HeteroPool,
    ModelArch,
    ParallelStrategy,
    SearchSpec,
    Workload,
)
from repro.core.hetero import (
    balanced_placement,
    compositions,
    enumerate_placements,
    layer_assignments,
)
from repro.core.memory import MemoryFilter, activation_bytes_per_layer, peak_stage_memory
from repro.core.params import HeteroPlacement, default_parameter_space
from repro.core.pareto import CostedStrategy, optimal_pool, pick_within_budget
from repro.core.search import generate_strategies, iter_raw_strategies
from repro.core.simulate import SimResult


def _strategy(llama7b, **kw) -> ParallelStrategy:
    base = dict(device="A800", num_devices=64, tensor_parallel=2,
                pipeline_parallel=2, micro_batch_size=1)
    base.update(kw)
    return ParallelStrategy(**base)


# ---------------------------------------------------------------------------
# search space (Eq. 8-9)
# ---------------------------------------------------------------------------

def test_raw_space_counts_match_eq9(llama7b):
    space = {
        "tensor_parallel": [1, 2],
        "pipeline_parallel": [1, 2],
        "micro_batch_size": [1, 2],
        "sequence_parallel": [False, True],
    }
    raw = list(iter_raw_strategies(llama7b, GpuConfig("A800", 8), 64, space=space))
    assert len(raw) == 2 * 2 * 2 * 2  # product of options (Eq. 9)


def test_divisibility_rules(llama7b):
    s = _strategy(llama7b, num_devices=60, tensor_parallel=8, pipeline_parallel=4)
    assert not s.is_divisible(llama7b, 512)  # 60 % 32 != 0
    s = _strategy(llama7b, num_devices=64, tensor_parallel=64)
    assert not s.is_divisible(llama7b, 512)  # tp > heads
    s = _strategy(llama7b, num_devices=64, tensor_parallel=8, pipeline_parallel=2)
    assert s.is_divisible(llama7b, 512)


def test_generate_strategies_funnel(llama7b):
    valid, counts = generate_strategies(
        llama7b, [GpuConfig("A800", 64)], 512, 4096
    )
    assert counts.generated >= counts.divisible >= counts.after_rules >= counts.after_memory
    assert counts.after_memory == len(valid) > 0
    for s in valid:
        assert s.is_divisible(llama7b, 512)


# ---------------------------------------------------------------------------
# memory filter (Eq. 20-21)
# ---------------------------------------------------------------------------

def test_memory_monotone_in_microbatch(llama7b):
    a1 = activation_bytes_per_layer(llama7b, _strategy(llama7b, micro_batch_size=1), 1, 4096)
    a4 = activation_bytes_per_layer(llama7b, _strategy(llama7b, micro_batch_size=4), 4, 4096)
    assert a4 == pytest.approx(4 * a1)


def test_memory_knobs_reduce_footprint(llama7b):
    base = _strategy(llama7b)
    seq = 4096
    m0, _ = peak_stage_memory(llama7b, base, seq=seq)
    for kw in (
        dict(sequence_parallel=True),
        dict(recompute_granularity="full"),
        dict(use_distributed_optimizer=True),
        dict(tensor_parallel=4),
    ):
        m1, _ = peak_stage_memory(llama7b, dataclasses.replace(base, **kw), seq=seq)
        assert m1 < m0, kw


def test_memory_filter_rejects_oom(llama7b):
    # 7B on a single A800 with no memory savings: optimizer states alone ~108GB
    s = ParallelStrategy(device="A800", num_devices=1, micro_batch_size=1)
    assert not MemoryFilter(seq=4096).is_valid(llama7b, s)
    # but 16-way sharded fits
    s = ParallelStrategy(device="A800", num_devices=32, tensor_parallel=4,
                         pipeline_parallel=4, micro_batch_size=1,
                         use_distributed_optimizer=True, sequence_parallel=True,
                         recompute_granularity="full", recompute_num_layers=8)
    assert MemoryFilter(seq=4096).is_valid(llama7b, s)


@given(mb=st.sampled_from([1, 2, 4]), seq=st.sampled_from([1024, 4096, 8192]))
@settings(max_examples=20, deadline=None)
def test_property_flash_attn_never_increases_activations(llama7b, mb, seq):
    no_flash = _strategy(llama7b, use_flash_attn=False, micro_batch_size=mb)
    flash = _strategy(llama7b, use_flash_attn=True, micro_batch_size=mb)
    assert activation_bytes_per_layer(llama7b, flash, mb, seq) <= activation_bytes_per_layer(
        llama7b, no_flash, mb, seq
    )


# ---------------------------------------------------------------------------
# cost model + Eq. 22
# ---------------------------------------------------------------------------

def test_eq22_reduces_to_gpipe_in_homogeneous_limit(llama7b):
    """Homogeneous stages: T = K*t + (P-1)*t == paper's classic formula."""
    sim = CostSimulator(AnalyticEtaModel())
    s = _strategy(llama7b, pipeline_parallel=4, tensor_parallel=2,
                  num_devices=64, micro_batch_size=1)
    res = sim.simulate(llama7b, s, global_batch=64, seq=2048)
    K = s.num_microbatches(64)
    t = max(res.stage_times[i] + res.stage_p2p[i] for i in range(4))
    # stage times differ slightly (embedding/head on edge stages); check the
    # formula structure with the actual per-stage values
    expected = sum(
        res.stage_times[i] + res.stage_p2p[i] for i in range(4)
    ) + (K - 1) * t
    assert res.pipeline_time == pytest.approx(expected, rel=1e-9)


def test_virtual_pipeline_invariants(llama7b):
    """Regression for the Eq.22 interleaving extension: vp must be a no-op
    without a pipeline (pp=1), must never beat the steady-state bound, and
    must strictly shrink the bubble when pp>1 and K>1."""
    sim = CostSimulator(AnalyticEtaModel())
    kw = dict(global_batch=64, seq=2048)
    base = _strategy(llama7b, pipeline_parallel=1, tensor_parallel=2,
                     num_devices=64, micro_batch_size=1)
    for vp in (1, 2, 4):
        s = dataclasses.replace(base, virtual_pipeline_stages=vp)
        r = sim.simulate(llama7b, s, **kw)
        if vp == 1:
            t_ref = r.step_time
        assert r.step_time == pytest.approx(t_ref, rel=1e-9), vp

    pp4 = _strategy(llama7b, pipeline_parallel=4, tensor_parallel=2,
                    num_devices=64, micro_batch_size=1)
    r1 = sim.simulate(llama7b, pp4, **kw)
    r2 = sim.simulate(
        llama7b, dataclasses.replace(pp4, virtual_pipeline_stages=2), **kw
    )
    assert r2.bubble_time < r1.bubble_time
    K = pp4.num_microbatches(64)
    assert r2.pipeline_time > K * max(
        r1.stage_times[i] + r1.stage_p2p[i] for i in range(4)
    ) * 0.99  # never below the steady-state lower bound


def test_more_devices_more_throughput(llama7b):
    sim = CostSimulator(AnalyticEtaModel())
    r64 = sim.simulate(llama7b, _strategy(llama7b, num_devices=64, tensor_parallel=2,
                                          pipeline_parallel=1),
                       global_batch=512, seq=4096)
    r128 = sim.simulate(llama7b, _strategy(llama7b, num_devices=128, tensor_parallel=2,
                                           pipeline_parallel=1),
                        global_batch=512, seq=4096)
    assert r128.throughput_tokens > r64.throughput_tokens


def test_h100_faster_than_a800(llama7b):
    sim = CostSimulator(AnalyticEtaModel())
    kw = dict(num_devices=64, tensor_parallel=2, pipeline_parallel=1, micro_batch_size=2)
    ra = sim.simulate(llama7b, _strategy(llama7b, device="A800", **kw),
                      global_batch=512, seq=4096)
    rh = sim.simulate(llama7b, _strategy(llama7b, device="H100", **kw),
                      global_batch=512, seq=4096)
    assert rh.throughput_tokens > 1.5 * ra.throughput_tokens


def test_recompute_costs_time_saves_memory(llama7b):
    sim = CostSimulator(AnalyticEtaModel())
    base = _strategy(llama7b, num_devices=64, micro_batch_size=2)
    full = dataclasses.replace(base, recompute_granularity="full", recompute_num_layers=16)
    r0 = sim.simulate(llama7b, base, global_batch=512, seq=4096)
    r1 = sim.simulate(llama7b, full, global_batch=512, seq=4096)
    assert r1.step_time > r0.step_time
    m0, _ = peak_stage_memory(llama7b, base, seq=4096)
    m1, _ = peak_stage_memory(llama7b, full, seq=4096)
    assert m1 < m0


# ---------------------------------------------------------------------------
# heterogeneous (Eq. 23)
# ---------------------------------------------------------------------------

def test_composition_count_matches_stars_and_bars():
    # unordered compositions of P into M nonneg parts with huge caps:
    # C(P + M - 1, M - 1)
    P, M = 8, 3
    got = len(list(compositions(P, M, [P] * M)))
    assert got == math.comb(P + M - 1, M - 1)


def test_layer_assignment_budget():
    for n in layer_assignments(32, (2, 2)):
        assert 2 * n[0] + 2 * n[1] == 32
        assert all(x >= 1 for x in n)


def test_enumerate_placements_respects_caps(llama7b):
    pool = HeteroPool(total_devices=64, type_caps=(("A800", 16), ("H100", 48)))
    for pl in enumerate_placements(llama7b, pool, pipeline_parallel=4,
                                   data_parallel=2, tensor_parallel=2):
        seq = pl.stage_sequence()
        assert len(seq) == 4
        assert pl.total_layers == llama7b.num_layers
        a800_stages = sum(1 for d, _ in seq if d == "A800")
        assert a800_stages * 4 <= 16  # m_i * D * T <= l_i


def test_balanced_placement_gives_faster_type_more_layers(llama7b):
    pool = HeteroPool(total_devices=64, type_caps=(("A800", 32), ("H100", 32)))
    pl = balanced_placement(llama7b, pool, pipeline_parallel=4, data_parallel=2,
                            tensor_parallel=2, m=(2, 2))
    assert pl is not None and pl.total_layers == 32
    layers = dict(zip(pl.devices, pl.layers_per_stage))
    assert layers["H100"] > layers["A800"]


def test_hetero_beats_worst_homogeneous(llama7b):
    """Mixed cluster should outperform its slowest-type-only half at the same
    total device count budget split (sanity direction check, as in Table 2)."""
    astra = Astra(AnalyticEtaModel())
    pool = HeteroPool(total_devices=32, type_caps=(("A800", 16), ("H100", 16)))
    w = Workload(global_batch=128, seq=2048)
    het = astra.search(SearchSpec(
        arch=llama7b, pool=HeteroCaps.of(pool), workload=w))
    hom = astra.search(SearchSpec(
        arch=llama7b, pool=FixedPool("A800", 32), workload=w))
    assert het.best_sim.throughput_tokens > 0
    assert hom.best_sim.throughput_tokens > 0
    # Table-2 relationship: heter >= all-A800, <= all-H100 at same count
    h100 = astra.search(SearchSpec(
        arch=llama7b, pool=FixedPool("H100", 32), workload=w))
    assert hom.best_sim.throughput_tokens <= h100.best_sim.throughput_tokens


# ---------------------------------------------------------------------------
# pareto / money (Eq. 29-33)
# ---------------------------------------------------------------------------

def _costed(p, c):
    sim = SimResult(step_time=1.0, throughput_samples=p, throughput_tokens=p,
                    pipeline_time=1, bubble_time=0, dp_exposed_time=0,
                    optimizer_time=0, stage_times=[1.0], stage_p2p=[0.0],
                    money_per_hour=c, money_per_step=c / 3600)
    return CostedStrategy(strategy=None, sim=sim, throughput=p, money=c)


def test_optimal_pool_no_dominated_pairs():
    cands = [_costed(10, 5), _costed(20, 4), _costed(5, 1), _costed(20, 9), _costed(1, 0.5)]
    pool = optimal_pool(cands)
    for a in pool:
        for b in pool:
            assert not (b.throughput > a.throughput and b.money < a.money)
    # the dominated (10,5) and (20,9) entries are gone
    assert {(c.throughput, c.money) for c in pool} == {(20, 4), (5, 1), (1, 0.5)}


def test_pick_within_budget():
    pool = optimal_pool([_costed(20, 4), _costed(5, 1), _costed(1, 0.5)])
    assert pick_within_budget(pool, 10).throughput == 20
    assert pick_within_budget(pool, 2).throughput == 5
    assert pick_within_budget(pool, 0.1) is None
    assert pick_within_budget(pool, None).throughput == 20


@given(
    st.lists(
        st.tuples(st.floats(0.1, 100), st.floats(0.1, 100)), min_size=1, max_size=40
    )
)
@settings(max_examples=50, deadline=None)
def test_property_pool_is_pareto_front(pairs):
    cands = [_costed(p, c) for p, c in pairs]
    pool = optimal_pool(cands)
    # 1) non-domination inside the pool
    for a in pool:
        assert not any(
            b.throughput > a.throughput and b.money < a.money for b in pool
        )
    # 2) every candidate is weakly dominated by some pool member
    for c in cands:
        assert any(
            p.throughput >= c.throughput and p.money <= c.money for p in pool
        )
