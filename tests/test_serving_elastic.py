"""Serving-workload search + elastic re-search, and the bug fixes that
unblock them: DeviceSweep count validation, the ServeEngine KV-overflow
guard, and warmup-step exclusion in emitted calibration traces.

The elastic assertions here are the PR's contract: an unchanged pool is a
byte-identical store hit with zero engine calls; a shrunk (or grown) pool
warm-starts from the prior report, evaluates strictly fewer candidates
than the cold search it replaces, and agrees with it on the winner.
"""
import dataclasses
import json

import numpy as np
import pytest

from harness_service import CountingAstra, http_service, request
from repro.calibration.fit import AnalyticEtaModel
from repro.calibration.traces import StepTrace
from repro.core import (
    Astra,
    DeviceSweep,
    FixedPool,
    InferenceShape,
    Limits,
    ObjectiveSpec,
    SearchReport,
    SearchSpec,
    Workload,
)
from repro.core.pareto import CellBest, CostedStrategy
from repro.core.params import ParallelStrategy
from repro.core.simulate import SimResult
from repro.serve.search_service import SearchService


# ---------------------------------------------------------------------------
# satellite fixes
# ---------------------------------------------------------------------------

def test_device_sweep_rejects_degenerate_min_devices():
    # min_devices=0 used to spin counts() forever (0 *= 2 stays 0)
    with pytest.raises(ValueError, match="min_devices"):
        DeviceSweep(("A800",), max_devices=8, min_devices=0)
    with pytest.raises(ValueError, match="min_devices"):
        DeviceSweep(("A800",), max_devices=2, min_devices=4)


def test_device_sweep_counts_terminate_and_cover_the_range():
    assert DeviceSweep(("A800",), 64).counts() == [2, 4, 8, 16, 32, 64]
    assert DeviceSweep(("A800",), 1, min_devices=1).counts() == [1]


def test_serve_engine_kv_overflow_raises(tiny_dense):
    import jax
    import jax.numpy as jnp

    from repro.models import lm
    from repro.serve import ServeEngine

    cfg = lm.ModelCfg(dtype=jnp.float32, attn_impl="xla", ssm_impl="xla")
    params = lm.init_params(tiny_dense, jax.random.PRNGKey(0))
    engine = ServeEngine(tiny_dense, cfg, params, max_len=8)
    prompts = np.zeros((1, 5), dtype=np.int32)
    # 5 + 4 > 8: positions past the cache end used to clobber it silently
    with pytest.raises(ValueError, match="max_len"):
        engine.generate(prompts, max_new_tokens=4)
    # frontend features occupy cache slots too and must be accounted
    with pytest.raises(ValueError, match="frontend_len"):
        engine.generate(
            prompts, max_new_tokens=1,
            frontend=jnp.zeros((1, 3, tiny_dense.hidden)),
        )
    # exactly filling the cache is fine
    result = engine.generate(prompts, max_new_tokens=3)
    assert result.tokens.shape == (1, 8)


def test_serve_engine_reports_warmup_steps_until_batch_is_warm(tiny_dense):
    import jax
    import jax.numpy as jnp

    from repro.models import lm
    from repro.serve import ServeEngine

    cfg = lm.ModelCfg(dtype=jnp.float32, attn_impl="xla", ssm_impl="xla")
    params = lm.init_params(tiny_dense, jax.random.PRNGKey(0))
    engine = ServeEngine(tiny_dense, cfg, params, max_len=16)
    prompts = np.zeros((2, 4), dtype=np.int32)
    first = engine.generate(prompts, max_new_tokens=3)
    assert first.warmup_steps == 1  # the compile landed in step_times[0]
    again = engine.generate(prompts, max_new_tokens=3)
    assert again.warmup_steps == 0  # batch shape already compiled
    # a new batch shape compiles its own executable
    other = engine.generate(np.zeros((3, 4), dtype=np.int32), max_new_tokens=2)
    assert other.warmup_steps == 1


def test_steptrace_warmup_exclusion_is_sparse_on_the_wire(tiny_dense):
    base = dict(
        arch=tiny_dense,
        strategy=ParallelStrategy(device="A800", num_devices=1),
        global_batch=8, seq=128, step_times=(0.5, 0.5), source="serve",
    )
    clean = StepTrace(**base)
    assert "warmup_steps_excluded" not in clean.to_dict()  # old bytes intact
    marked = StepTrace(**base, warmup_steps_excluded=1)
    assert marked.to_dict()["warmup_steps_excluded"] == 1
    assert StepTrace.from_dict(marked.to_dict()) == marked
    with pytest.raises(ValueError, match="warmup_steps_excluded"):
        StepTrace(**base, warmup_steps_excluded=-1)


# ---------------------------------------------------------------------------
# serving workload: spec wire + search semantics
# ---------------------------------------------------------------------------

INF = InferenceShape(prefill_len=256, decode_len=64, slo_per_token=0.5)


def _serving_spec(llama7b, n=8, inf=INF, objective=None):
    return SearchSpec(
        arch=llama7b,
        pool=DeviceSweep(("A800",), max_devices=n, min_devices=2),
        workload=Workload(global_batch=32, seq=4096, inference=inf),
        objective=objective or ObjectiveSpec.latency(),
        limits=Limits(top_k=5),
    )


def test_serving_spec_wire_roundtrip(llama7b):
    spec = _serving_spec(llama7b, inf=InferenceShape(
        prefill_len=256, decode_len=64,
        batch_mix=((8, 1.0), (32, 3.0)), slo_per_token=0.25,
    ))
    assert SearchSpec.from_json(spec.to_json()) == spec
    assert SearchSpec.from_json(spec.to_json()).cache_key() == spec.cache_key()


def test_training_spec_wire_has_no_inference_key(llama7b):
    # back-compat: a training spec's wire bytes and cache key must be
    # exactly what they were before InferenceShape existed
    spec = SearchSpec(
        arch=llama7b,
        pool=FixedPool("A800", 8),
        workload=Workload(global_batch=64, seq=2048),
    )
    assert "inference" not in json.dumps(spec.to_dict())
    assert "inference" not in spec.canonical_json()


def test_family_key_ignores_the_pool_and_nothing_else(llama7b):
    a = _serving_spec(llama7b, n=8)
    b = _serving_spec(llama7b, n=32)
    assert a.cache_key() != b.cache_key()
    assert a.family_key() == b.family_key()
    other = dataclasses.replace(
        a, workload=dataclasses.replace(a.workload, global_batch=64)
    )
    assert other.family_key() != a.family_key()


def test_serving_search_returns_cheapest_meeting_slo(llama7b):
    report = Astra(AnalyticEtaModel()).search(_serving_spec(llama7b))
    assert report.best is not None
    assert report.best_sim.step_time <= INF.slo_per_token
    # cheapest: the winner is top-ranked and no other SLO-satisfier in the
    # ranking costs less
    best_c = report.top[0]
    assert best_c.strategy == report.best
    assert all(
        c.money >= best_c.money
        for c in report.top[1:] if c.sim.step_time <= INF.slo_per_token
    )
    # per-cell champions cover every swept cell that had a valid candidate
    covered = {(c.strategy.device, c.strategy.num_devices)
               for c in report.cells}
    assert covered  # serving sweeps keep their champions


def test_serving_search_infeasible_slo_returns_none(llama7b):
    spec = _serving_spec(llama7b, inf=InferenceShape(
        prefill_len=256, decode_len=64, slo_per_token=1e-9,
    ))
    report = Astra(AnalyticEtaModel()).search(spec)
    assert report.best is None and report.best_sim is None
    assert report.evaluated > 0  # it searched; nothing met the SLO


# ---------------------------------------------------------------------------
# elastic re-search
# ---------------------------------------------------------------------------

def test_elastic_unchanged_pool_is_byte_identical_with_zero_searches(llama7b):
    counting = CountingAstra()
    svc = SearchService(counting)
    spec = _serving_spec(llama7b)
    _, cold_text, cached = svc.search_json(spec.to_json(), elastic=True)
    assert not cached and counting.calls == 1
    _, warm_text, cached = svc.search_json(spec.to_json(), elastic=True)
    assert cached and counting.calls == 1  # store hit, zero engine calls
    assert warm_text == cold_text  # byte-identical, not merely equal
    assert svc.stats_dict()["elastic_searches"] == 2
    assert svc.stats_dict()["elastic_warm_starts"] == 0


def test_elastic_shrink_does_strictly_less_work_and_agrees_on_best(llama7b):
    svc = SearchService(Astra(AnalyticEtaModel()))
    svc.search_json(_serving_spec(llama7b, n=16).to_json())
    shrunk = _serving_spec(llama7b, n=8)
    _, text, _ = svc.search_json(shrunk.to_json(), elastic=True)
    elastic = SearchReport.from_json(text)
    assert svc.stats_dict()["elastic_warm_starts"] == 1

    cold = Astra(AnalyticEtaModel()).search(shrunk)
    assert elastic.best == cold.best
    assert elastic.best_sim == cold.best_sim
    assert elastic.evaluated < cold.evaluated
    # every funnel rung strictly shrinks: the warm start is auditable
    for rung in ("generated", "divisible", "after_rules", "after_memory"):
        assert getattr(elastic.counts, rung) < getattr(cold.counts, rung)


def test_elastic_grow_streams_only_the_new_region(llama7b):
    svc = SearchService(Astra(AnalyticEtaModel()))
    svc.search_json(_serving_spec(llama7b, n=8).to_json())
    grown = _serving_spec(llama7b, n=16)
    _, text, _ = svc.search_json(grown.to_json(), elastic=True)
    elastic = SearchReport.from_json(text)
    assert svc.stats_dict()["elastic_warm_starts"] == 1

    cold = Astra(AnalyticEtaModel()).search(grown)
    assert elastic.best == cold.best
    assert elastic.evaluated < cold.evaluated


def test_elastic_applies_to_training_sweeps_too(llama7b):
    # elastic is not serving-only: any cell-decomposable pool warm-starts
    svc = SearchService(Astra(AnalyticEtaModel()))
    spec16 = SearchSpec(
        arch=llama7b,
        pool=DeviceSweep(("A800",), 16),
        workload=Workload(global_batch=64, seq=2048),
        objective=ObjectiveSpec.pareto(None),
    )
    svc.search_json(spec16.to_json())
    spec8 = dataclasses.replace(spec16, pool=DeviceSweep(("A800",), 8))
    _, text, _ = svc.search_json(spec8.to_json(), elastic=True)
    elastic = SearchReport.from_json(text)
    assert svc.stats_dict()["elastic_warm_starts"] == 1
    cold = Astra(AnalyticEtaModel()).search(spec8)
    assert elastic.best == cold.best
    assert elastic.evaluated < cold.evaluated


def test_elastic_without_a_prior_falls_back_to_cold(llama7b):
    counting = CountingAstra()
    svc = SearchService(counting)
    _, text, cached = svc.search_json(
        _serving_spec(llama7b).to_json(), elastic=True
    )
    assert not cached and counting.calls == 1
    assert svc.stats_dict()["elastic_warm_starts"] == 0
    assert SearchReport.from_json(text).best is not None


def test_elastic_over_http_query_param(llama7b):
    svc = SearchService(Astra(AnalyticEtaModel()))
    small, big = _serving_spec(llama7b, n=8), _serving_spec(llama7b, n=16)
    with http_service(svc) as url:
        status, cold = request(
            f"{url}/v1/search", big.to_json().encode()
        )
        assert status == 200
        status, warm = request(
            f"{url}/v1/search?elastic=1", small.to_json().encode()
        )
        assert status == 200
        status, stats = request(f"{url}/v1/stats")
    assert stats["elastic_searches"] == 1
    assert stats["elastic_warm_starts"] == 1
    assert warm["report"]["evaluated"] < cold["report"]["evaluated"]


# ---------------------------------------------------------------------------
# per-cell champions (the elastic seed set)
# ---------------------------------------------------------------------------

def _costed(device, n, money, thr):
    s = ParallelStrategy(device=device, num_devices=n)
    sim = SimResult(
        step_time=1.0, throughput_samples=thr, throughput_tokens=thr,
        pipeline_time=0.0, bubble_time=0.0, dp_exposed_time=0.0,
        optimizer_time=0.0, stage_times=[], stage_p2p=[],
        money_per_hour=money, money_per_step=money,
    )
    return CostedStrategy(strategy=s, sim=sim, throughput=thr, money=money)


def test_cellbest_keeps_one_champion_per_cell():
    cb = CellBest()
    cb.push(_costed("A800", 8, 1.0, 100.0))
    cb.push(_costed("A800", 8, 1.0, 200.0))  # better throughput, same cell
    cb.push(_costed("A800", 16, 1.0, 50.0))
    champs = cb.sorted()
    assert [(c.strategy.num_devices, c.throughput) for c in champs] == \
        [(8, 200.0), (16, 50.0)]


def test_cellbest_merge_matches_single_pass():
    cands = [_costed("A800", 4 * (1 + i % 3), float(i % 5), float(i))
             for i in range(30)]
    single = CellBest()
    for c in cands:
        single.push(c)
    left, right = CellBest(), CellBest()
    for i, c in enumerate(cands):
        (left if i % 2 else right).push(c, seq=(i,))
    left.merge(right)
    assert [c for _, c in left.entries()] == [c for _, c in single.entries()]


def test_cellbest_ties_break_toward_earlier_stream_position():
    cb = CellBest()
    first, second = _costed("A800", 8, 1.0, 10.0), _costed("A800", 8, 1.0, 10.0)
    cb.push(first, seq=(0,))
    cb.push(second, seq=(1,))
    assert cb.sorted()[0] is first  # identical key: earliest seq wins


def test_report_cells_survive_the_wire(llama7b):
    rep = Astra(AnalyticEtaModel()).search(_serving_spec(llama7b))
    assert rep.cells
    assert SearchReport.from_json(rep.to_json()) == rep
    # training reports on a FixedPool carry their single cell too, sparse
    # on the wire only when empty
    assert "cells" in rep.to_dict()
