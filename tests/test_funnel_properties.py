"""Property tests for the columnar funnel (hypothesis; module skips when
hypothesis is unavailable, mirroring tests/test_rules.py).

Three oracles, each randomized:

* compiled rule block-masks == the per-candidate interpreter, including
  rules that defeat mask compilation (fallback path),
* flat-forest GBT ``predict`` == ``predict_reference`` bit-for-bit,
* vectorized funnel == scalar funnel (survivors, raw indices, counts)
  over randomized sub-spaces of the default parameter space.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.arch import ModelArch
from repro.core.params import GpuConfig, default_parameter_space
from repro.core.rules import CategoricalColumn, Rule, RuleFilter
from repro.core.search import SearchCounts, iter_valid_strategies
from repro.gbt import GradientBoostedTrees
from repro.hw.catalog import get_device

# ---------------------------------------------------------------------------
# compiled masks vs interpreter
# ---------------------------------------------------------------------------

# a rule set spanning the mask compiler's surface: arithmetic, modulo,
# precedence, categorical equality, truthiness, short-circuits — plus two
# rules with NO faithful block evaluation (categorical-vs-categorical
# comparison; ordered comparison on a categorical) that must route through
# the per-candidate fallback
_RULES = [
    "$a % $b = 0",
    "$g = full && $a > 4",
    "$flag != none || $c < 2",
    "$a * 2 + $c >= $b * 3",
    "$g != none",
    "$a - $b > $c || $g = selective && $flag = true",
    "$g = $h",  # MaskCompileError: two categorical columns
]

_CATS = ("none", "selective", "full")


def _columns(rows):
    def cat(key):
        vals = sorted({r[key] for r in rows})
        codes = np.array([vals.index(r[key]) for r in rows], dtype=np.int64)
        return CategoricalColumn(vals, codes)

    return {
        "a": np.array([r["a"] for r in rows], dtype=np.int64),
        "b": np.array([r["b"] for r in rows], dtype=np.int64),
        "c": np.array([r["c"] for r in rows], dtype=np.int64),
        "flag": np.array([r["flag"] for r in rows], dtype=bool),
        "g": cat("g"),
        "h": cat("h"),
    }


_row = st.fixed_dictionaries({
    "a": st.integers(0, 16),
    "b": st.integers(1, 8),  # never 0: both paths would raise on % 0
    "c": st.integers(-4, 4),
    "flag": st.booleans(),
    "g": st.sampled_from(_CATS),
    "h": st.sampled_from(_CATS),
})


@given(rows=st.lists(_row, min_size=1, max_size=64))
@settings(max_examples=50, deadline=None)
def test_property_block_masks_match_interpreter(rows):
    f = RuleFilter(_RULES)
    env = _columns(rows)
    got = f.block_violations(env, len(rows), lambda i: rows[i])
    want = np.array([not f.is_valid(r) for r in rows], dtype=bool)
    assert np.array_equal(got, want)


@given(rows=st.lists(_row, min_size=1, max_size=32), data=st.data())
@settings(max_examples=25, deadline=None)
def test_property_single_rule_mask(rows, data):
    text = data.draw(st.sampled_from(_RULES[:-1]))  # last needs fallback
    r = Rule.parse(text)
    env = _columns(rows)
    got = r.block_mask(env, len(rows))
    want = np.array([r.matches(row) for row in rows], dtype=bool)
    assert np.array_equal(got, want)


# ---------------------------------------------------------------------------
# flat-forest GBT vs recursive reference
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 2**16), nan_frac=st.floats(0.0, 0.3))
@settings(max_examples=10, deadline=None)
def test_property_flat_forest_bit_identical(seed, nan_frac):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((300, 6))
    y = X[:, 0] * 2 - X[:, 1] + 0.1 * rng.standard_normal(300)
    m = GradientBoostedTrees(n_estimators=25, max_depth=4, seed=seed).fit(X, y)
    Xq = rng.standard_normal((128, 6))
    mask = rng.uniform(size=Xq.shape) < nan_frac
    Xq[mask] = np.nan
    assert np.array_equal(m.predict(Xq), m.predict_reference(Xq))
    m2 = GradientBoostedTrees.from_dict(m.to_dict())
    assert np.array_equal(m2.predict(Xq), m.predict(Xq))


# ---------------------------------------------------------------------------
# vectorized funnel vs scalar funnel over randomized sub-spaces
# ---------------------------------------------------------------------------

_ARCH = ModelArch(
    name="tiny-prop", family="dense", num_layers=4, hidden=128,
    heads=8, kv_heads=4, ffn=512, vocab=256,
)
_GB, _SEQ = 64, 2048


def _subspace(data):
    gpu = GpuConfig("A100", 8)
    base = default_parameter_space(
        _ARCH, gpu.num_devices, get_device(gpu.device).devices_per_node, _GB
    )
    space = {}
    for k, vals in base.items():
        keep = data.draw(
            st.lists(st.sampled_from(vals), min_size=1, max_size=len(vals),
                     unique=True),
            label=k,
        )
        space[k] = sorted(keep, key=vals.index)
    return gpu, space


@given(data=st.data())
@settings(max_examples=15, deadline=None)
def test_property_vectorized_funnel_parity(data):
    gpu, space = _subspace(data)
    out = {}
    for vec in (True, False):
        counts = SearchCounts()
        out[vec] = (
            list(iter_valid_strategies(
                _ARCH, [gpu], _GB, _SEQ, space=space, counts=counts,
                indexed=True, vectorize=vec,
            )),
            counts.normalized(),
        )
    assert out[True] == out[False]


@given(data=st.data(), n=st.integers(2, 4))
@settings(max_examples=10, deadline=None)
def test_property_shard_union_is_serial(data, n):
    gpu, space = _subspace(data)
    counts = SearchCounts()
    serial = list(iter_valid_strategies(
        _ARCH, [gpu], _GB, _SEQ, space=space, counts=counts,
        indexed=True, vectorize=True,
    ))
    union, merged = [], SearchCounts()
    for i in range(n):
        c = SearchCounts()
        union.extend(iter_valid_strategies(
            _ARCH, [gpu], _GB, _SEQ, space=space, counts=c,
            indexed=True, shard=(i, n), vectorize=True,
        ))
        merged.merge(c)
    assert sorted(union, key=lambda p: p[0]) == serial
    assert merged.normalized() == counts.normalized()
