"""Training substrate: optimizer, schedules, grad accumulation, convergence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.data import MarkovCorpus, SyntheticPipeline
from repro.models import lm
from repro.train.optimizer import adamw_init, adamw_update, cosine_schedule, global_norm
from repro.train.train_step import TrainStepCfg, make_train_step

CFG = lm.ModelCfg(dtype=jnp.float32, attn_impl="xla", ssm_impl="xla")


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(params, grads, opt, lr=0.1, weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_clipping():
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params)
    big = {"w": jnp.full(3, 1e6)}
    _, _, metrics = adamw_update(params, big, opt, lr=0.0, clip_norm=1.0)
    assert metrics["grad_norm"] > 1e6  # reported norm is pre-clip


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup_steps=10, total_steps=100, min_ratio=0.1)
    assert float(lr(jnp.array(0))) == 0.0
    assert float(lr(jnp.array(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(lr(jnp.array(100))) == pytest.approx(0.1, rel=1e-2)
    assert float(lr(jnp.array(5))) == pytest.approx(0.5, rel=1e-6)


def test_global_norm():
    t = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)


def test_grad_accumulation_matches_single_batch():
    """K-microbatch accumulated grads == one-shot grads of the mean loss.

    (Post-Adam params are NOT compared: eps-nonlinearity amplifies fp32
    summation-order noise on near-zero gradient entries.)
    """
    arch = get_reduced("yi-6b")
    params = lm.init_params(arch, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, arch.vocab)}

    def loss_of(p, b):
        return lm.forward_train(p, arch, CFG, b)[0]

    g_full = jax.grad(loss_of)(params, batch)
    K = 4
    micro = jax.tree_util.tree_map(
        lambda x: x.reshape((K, x.shape[0] // K) + x.shape[1:]), batch
    )
    g_acc = jax.tree_util.tree_map(jnp.zeros_like, params)
    for i in range(K):
        mb = jax.tree_util.tree_map(lambda x: x[i], micro)
        g = jax.grad(loss_of)(params, mb)
        g_acc = jax.tree_util.tree_map(lambda a, b: a + b / K, g_acc, g)
    rel = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max() / (jnp.abs(a).max() + 1e-9)),
        g_full, g_acc,
    )
    assert max(jax.tree_util.tree_leaves(rel)) < 1e-4
    # and the loss metric agrees between the two train_step paths
    losses = {}
    for k in (1, 4):
        cfg = TrainStepCfg(num_microbatches=k, base_lr=1e-2, warmup_steps=0,
                           total_steps=10)
        _, _, m = make_train_step(arch, CFG, cfg)(params, adamw_init(params), batch)
        losses[k] = float(m["loss"])
    assert losses[1] == pytest.approx(losses[4], rel=1e-5)


def test_loss_decreases_toward_entropy_floor():
    arch = get_reduced("qwen3-8b")
    corpus = MarkovCorpus(arch.vocab, seed=0)
    pipe = SyntheticPipeline(corpus=corpus, global_batch=16, seq_len=64)
    cfg = TrainStepCfg(num_microbatches=1, base_lr=3e-3, warmup_steps=5,
                       total_steps=60)
    step = jax.jit(make_train_step(arch, CFG, cfg))
    params = lm.init_params(arch, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    losses = []
    for _ in range(60):
        batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    floor = corpus.entropy_rate()
    assert losses[-1] < losses[0] - 1.0
    assert losses[-1] < floor + 1.5  # approaching the markov entropy rate
    assert np.isfinite(losses).all()


def test_bf16_grad_accumulation_close_to_fp32():
    arch = get_reduced("yi-6b")
    params = lm.init_params(arch, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, arch.vocab)}
    p32, _, _ = make_train_step(arch, CFG, TrainStepCfg(num_microbatches=4))(
        params, adamw_init(params), batch)
    p16, _, _ = make_train_step(
        arch, CFG, TrainStepCfg(num_microbatches=4, accum_dtype=jnp.bfloat16)
    )(params, adamw_init(params), batch)
    rel = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max() / (jnp.abs(a).max() + 1e-9)), p32, p16
    )
    assert max(jax.tree_util.tree_leaves(rel)) < 0.05
