"""Fleet execution: /v1/shard worker contract, coordinator work-stealing,
retry/reassignment under injected worker failure, and byte-identity of the
fleet report with the serial one for all three pool shapes."""
import contextlib
import dataclasses
import json
import socket

import pytest

from repro.calibration.fit import AnalyticEtaModel
from repro.core import (
    Astra,
    DeviceSweep,
    FixedPool,
    HeteroCaps,
    Limits,
    ObjectiveSpec,
    SearchSpec,
    Workload,
)
from repro.core.backend import FleetBackend, FleetError
from repro.core.objectives import make_objective
from repro.core.planner import pool_mode
from repro.serve.search_service import AuthQuota, SearchService, TokenInfo

from harness_service import CountingAstra, FlakyWorker, http_service, request


def _specs(tiny_dense):
    w = Workload(32, 512)
    return {
        "fixed": SearchSpec(
            arch=tiny_dense, pool=FixedPool("A800", 8), workload=w,
        ),
        "hetero": SearchSpec(
            arch=tiny_dense,
            pool=HeteroCaps(8, (("A800", 4), ("H100", 4))),
            workload=w,
        ),
        "sweep": SearchSpec(
            arch=tiny_dense,
            pool=DeviceSweep(("A800", "H100"), 8),
            workload=w,
            objective=ObjectiveSpec.pareto(None),
        ),
    }


def _worker_service(engine=None) -> SearchService:
    return SearchService(engine if engine is not None
                         else Astra(AnalyticEtaModel()))


@contextlib.contextmanager
def _fleet(engines):
    """Run one worker service per engine; yield their base URLs."""
    with contextlib.ExitStack() as stack:
        yield [
            stack.enter_context(http_service(_worker_service(e)))
            for e in engines
        ]


def _report_of(backend, spec):
    """Run a spec through an explicit backend via the Astra facade."""
    return Astra(AnalyticEtaModel(), backend=backend).search(spec)


def _dead_url() -> str:
    """An address nothing listens on (bound then closed)."""
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return f"http://127.0.0.1:{port}"


# ---------------------------------------------------------------------------
# the worker contract: POST /v1/shard
# ---------------------------------------------------------------------------

def test_shard_endpoint_contract(tiny_dense):
    spec = _specs(tiny_dense)["fixed"]
    svc = _worker_service()
    with http_service(svc) as base:
        body = json.dumps(
            {"spec": spec.canonicalize(), "shard": [0, 2]}
        ).encode()
        status, payload = request(f"{base}/v1/shard", body)
        assert status == 200
        assert payload["kind"] == "astra.shard_result"
        assert payload["shard"] == [0, 2]
        assert payload["evaluated"] > 0

        status, payload = request(f"{base}/v1/shard", b"not json")
        assert status == 400 and "bad shard request" in payload["error"]
        status, payload = request(
            f"{base}/v1/shard", json.dumps({"spec": {}}).encode()
        )
        assert status == 400
        # shard indices out of range are a caller bug, not a 500
        bad = json.dumps(
            {"spec": spec.canonicalize(), "shard": [2, 2]}
        ).encode()
        status, payload = request(f"{base}/v1/shard", bad)
        assert status == 400
    assert svc.stats.shards == 1
    assert svc.stats.shard_errors == 1  # only the evaluated bad-shard call


def test_shard_endpoint_501_without_engine_support(tiny_dense):
    spec = _specs(tiny_dense)["fixed"]
    svc = SearchService(CountingAstra())  # no run_shard on the engine
    with http_service(svc) as base:
        body = json.dumps(
            {"spec": spec.canonicalize(), "shard": [0, 2]}
        ).encode()
        status, payload = request(f"{base}/v1/shard", body)
    assert status == 501
    assert "shard" in payload["error"]


def test_shard_endpoint_requires_auth_but_not_cold_quota(tiny_dense):
    spec = _specs(tiny_dense)["fixed"]
    auth = AuthQuota([TokenInfo("tok", "ci", None, 0)])  # zero cold quota
    svc = _worker_service()
    with http_service(svc, auth=auth) as base:
        body = json.dumps(
            {"spec": spec.canonicalize(), "shard": [0, 2]}
        ).encode()
        status, _ = request(f"{base}/v1/shard", body)
        assert status == 401  # no token
        # shards never spend the cold quota: admitted despite COLD=0
        status, payload = request(f"{base}/v1/shard", body, token="tok")
        assert status == 200 and payload["kind"] == "astra.shard_result"


# ---------------------------------------------------------------------------
# fleet == serial, all three pool shapes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", ["fixed", "hetero", "sweep"])
def test_fleet_report_is_byte_identical_to_serial(tiny_dense, shape):
    spec = _specs(tiny_dense)[shape]
    serial = Astra(AnalyticEtaModel()).search(spec)
    with _fleet([None, None]) as urls:
        fleet = Astra(AnalyticEtaModel()).search(
            dataclasses.replace(spec, limits=Limits(fleet=tuple(urls)))
        )
    assert fleet.normalized_json() == serial.normalized_json()
    assert fleet.mode == pool_mode(spec.pool)
    # fleet is an execution detail: one cache key either way
    assert dataclasses.replace(
        spec, limits=Limits(fleet=("http://x", "http://y"))
    ).cache_key() == spec.cache_key()


def test_fleet_overshards_and_both_workers_contribute(tiny_dense):
    spec = _specs(tiny_dense)["sweep"]
    with _fleet([None, None]) as urls:
        backend = FleetBackend(urls)
        report = _report_of(backend, spec)
    stats = backend.last_run_stats
    assert stats["shards"] > 2  # oversharded beyond the worker count
    assert stats["completed"] == stats["shards"]
    assert sum(stats["assignments"].values()) == stats["shards"]
    # the queue is shared: with healthy workers both drain some of it
    assert all(n > 0 for n in stats["assignments"].values())
    assert report.normalized_json() == \
        Astra(AnalyticEtaModel()).search(spec).normalized_json()


# ---------------------------------------------------------------------------
# failure injection: death, garbage, timeout -> reassignment, same bytes
# ---------------------------------------------------------------------------

@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning"
)
@pytest.mark.parametrize("mode", ["die", "garbage"])
def test_fleet_reassigns_failed_shards(tiny_dense, mode):
    spec = _specs(tiny_dense)["hetero"]
    flaky = FlakyWorker(mode, fail_first=2)
    with _fleet([flaky, None]) as urls:
        backend = FleetBackend(urls)
        report = _report_of(backend, spec)
    assert flaky.failures_injected == 2
    stats = backend.last_run_stats
    assert stats["reassigned"] >= 2
    assert len(stats["errors"]) >= 2
    assert stats["completed"] == stats["shards"]
    assert report.normalized_json() == \
        Astra(AnalyticEtaModel()).search(spec).normalized_json()


def test_fleet_reassigns_timed_out_shards(tiny_dense):
    spec = _specs(tiny_dense)["fixed"]
    flaky = FlakyWorker("timeout", fail_first=1)
    try:
        with _fleet([flaky, None]) as urls:
            backend = FleetBackend(urls, timeout=0.5)
            report = _report_of(backend, spec)
            flaky.release.set()  # unpark the stalled handler before teardown
        assert flaky.failures_injected == 1
        assert backend.last_run_stats["reassigned"] >= 1
        assert report.normalized_json() == \
            Astra(AnalyticEtaModel()).search(spec).normalized_json()
    finally:
        flaky.release.set()


def test_fleet_survives_a_fully_dead_worker(tiny_dense):
    """One worker that was never up: every one of its pulls fails, it is
    retired, and the live worker steals the whole queue."""
    spec = _specs(tiny_dense)["fixed"]
    with _fleet([None]) as urls:
        backend = FleetBackend([urls[0], _dead_url()], timeout=5.0)
        report = _report_of(backend, spec)
    stats = backend.last_run_stats
    assert stats["completed"] == stats["shards"]
    assert stats["assignments"][backend.urls[0]] == stats["shards"]
    assert report.normalized_json() == \
        Astra(AnalyticEtaModel()).search(spec).normalized_json()


def test_fleet_all_workers_dead_raises(tiny_dense):
    spec = _specs(tiny_dense)["fixed"]
    backend = FleetBackend([_dead_url(), _dead_url()], timeout=1.0)
    objective = make_objective(spec.objective,
                               train_tokens=spec.workload.train_tokens)
    with pytest.raises(FleetError, match="incomplete"):
        backend.run(spec, objective)
    assert backend.last_run_stats["completed"] == 0
    assert backend.last_run_stats["errors"]


def test_fleet_rejects_capped_specs(tiny_dense):
    spec = dataclasses.replace(
        _specs(tiny_dense)["fixed"], limits=Limits(max_candidates=10)
    )
    backend = FleetBackend([_dead_url()])
    objective = make_objective(spec.objective,
                               train_tokens=spec.workload.train_tokens)
    with pytest.raises(ValueError, match="max_candidates"):
        backend.run(spec, objective)
    # and the facade never routes a capped spec to the fleet
    report = Astra(AnalyticEtaModel()).search(
        dataclasses.replace(
            spec,
            limits=Limits(max_candidates=10, fleet=(_dead_url(),)),
        )
    )
    assert report.evaluated == 10


# ---------------------------------------------------------------------------
# coordinator role: fleet searches land in the service store
# ---------------------------------------------------------------------------

def test_coordinator_caches_fleet_results(tiny_dense):
    spec = _specs(tiny_dense)["fixed"]
    worker_svc = _worker_service()
    with http_service(worker_svc) as url:
        coordinator = SearchService(
            Astra(AnalyticEtaModel(), backend=FleetBackend([url]))
        )
        r1 = coordinator.search(spec)
        shards_after_cold = worker_svc.stats.shards
        assert shards_after_cold > 0  # the fleet actually ran it
        r2 = coordinator.search(spec)
        # warm hit: served from the coordinator's store, workers untouched
        assert worker_svc.stats.shards == shards_after_cold
    assert coordinator.stats.hits == 1 and coordinator.stats.misses == 1
    assert r1.normalized_json() == r2.normalized_json()
    assert r1.normalized_json() == \
        Astra(AnalyticEtaModel()).search(spec).normalized_json()


def test_fleet_worker_plays_both_roles(tiny_dense):
    """One service can serve /v1/search and /v1/shard at once — the 'one
    binary, both parts' property."""
    spec = _specs(tiny_dense)["fixed"]
    svc = _worker_service()
    with http_service(svc) as base:
        status, _ = request(
            f"{base}/v1/search", spec.to_json().encode()
        )
        assert status == 200
        body = json.dumps(
            {"spec": spec.canonicalize(), "shard": [1, 3]}
        ).encode()
        status, payload = request(f"{base}/v1/shard", body)
        assert status == 200 and payload["shard"] == [1, 3]
    assert svc.stats.shards == 1 and svc.stats.misses == 1
