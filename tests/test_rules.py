"""Rule DSL (paper §3.3 Eq. 10-19): parsing, precedence, evaluation."""
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, strategies as st

from repro.core.rules import DEFAULT_RULES, Rule, RuleFilter, RuleSyntaxError, tokenize


def test_tokenize_basic():
    assert tokenize("$a && $b || $c != 3") == ["$a", "&&", "$b", "||", "$c", "!=", "3"]


def test_paper_rule_1_flash_selective():
    r = Rule.parse("$use_flash_attn != none && $recompute_granularity = selective")
    assert r.matches({"use_flash_attn": True, "recompute_granularity": "selective"})
    assert not r.matches({"use_flash_attn": True, "recompute_granularity": "full"})
    assert not r.matches({"use_flash_attn": None, "recompute_granularity": "selective"})


def test_paper_rule_2_recompute_layers():
    r = Rule.parse("$recompute_num_layers > $pipeline_model_parallel_size")
    assert r.matches({"recompute_num_layers": 9, "pipeline_model_parallel_size": 8})
    assert not r.matches({"recompute_num_layers": 8, "pipeline_model_parallel_size": 8})


def test_paper_rule_3_gpu_division():
    r = Rule.parse(
        "$num_gpus % ($pipeline_model_parallel_size * $tensor_model_parallel_size) != 0"
    )
    assert not r.matches(
        {"num_gpus": 64, "pipeline_model_parallel_size": 4, "tensor_model_parallel_size": 8}
    )
    assert r.matches(
        {"num_gpus": 60, "pipeline_model_parallel_size": 4, "tensor_model_parallel_size": 8}
    )


def test_and_binds_tighter_than_or():
    # a || b && c  ==  a || (b && c)
    r = Rule.parse("$a = 1 || $b = 1 && $c = 1")
    assert r.matches({"a": 1, "b": 0, "c": 0})
    assert not r.matches({"a": 0, "b": 1, "c": 0})
    assert r.matches({"a": 0, "b": 1, "c": 1})


def test_left_to_right_chains():
    r = Rule.parse("$a = 1 && $b = 1 && $c = 1")
    assert r.matches({"a": 1, "b": 1, "c": 1})
    assert not r.matches({"a": 1, "b": 1, "c": 0})


def test_arithmetic_precedence():
    r = Rule.parse("$x + 2 * 3 = 10")
    assert r.matches({"x": 4})
    r2 = Rule.parse("($x + 2) * 3 = 18")
    assert r2.matches({"x": 4})


def test_hyphenated_megatron_names():
    r = Rule.parse("$tensor-model-parallel-size > 8")
    assert r.matches({"tensor_model_parallel_size": 16})


def test_unknown_variable_raises():
    r = Rule.parse("$nope = 1")
    with pytest.raises(KeyError):
        r.matches({"a": 1})


def test_syntax_errors():
    for bad in ("$a &&", "(($a = 1)", "$a = = 1", "@bad"):
        with pytest.raises(RuleSyntaxError):
            Rule.parse(bad)


def test_filter_semantics_all_rules_must_be_false():
    f = RuleFilter(["$a = 1", "$b = 1"])
    assert f.is_valid({"a": 0, "b": 0})
    assert not f.is_valid({"a": 1, "b": 0})
    assert f.first_violation({"a": 0, "b": 1}) == "$b = 1"


def test_default_rules_parse():
    f = RuleFilter(DEFAULT_RULES)
    env = {
        "use_flash_attn": True,
        "recompute_granularity": "none",
        "recompute_num_layers": 0,
        "pipeline_model_parallel_size": 2,
        "tensor_model_parallel_size": 4,
        "num_gpus": 64,
    }
    assert f.is_valid(env)


@given(
    a=st.integers(0, 1), b=st.integers(0, 1), c=st.integers(0, 1), d=st.integers(0, 1)
)
def test_property_dsl_matches_python_semantics(a, b, c, d):
    """DSL result == python eval with the same precedence, for all inputs."""
    r = Rule.parse("$a = 1 && $b = 1 || $c = 1 && $d != 1")
    expected = (a == 1 and b == 1) or (c == 1 and d != 1)
    assert r.matches({"a": a, "b": b, "c": c, "d": d}) == expected


@given(x=st.integers(-1000, 1000), y=st.integers(1, 64))
def test_property_modulo(x, y):
    r = Rule.parse("$x % $y = 0")
    assert r.matches({"x": x, "y": y}) == (x % y == 0)
