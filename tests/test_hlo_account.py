"""HLO call-graph accountant: scan/unroll parity + collective accounting."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_account import account
from repro.launch.roofline import RooflineReport


def _scanned(x, w):
    def body(c, wl):
        return jnp.tanh(c @ wl), None

    y, _ = jax.lax.scan(body, x, w)
    return y.sum()


def _unrolled(x, w):
    for i in range(8):
        x = jnp.tanh(x @ w[i])
    return x.sum()


@pytest.fixture(scope="module")
def structs():
    return (
        jax.ShapeDtypeStruct((128, 256), jnp.float32),
        jax.ShapeDtypeStruct((8, 256, 256), jnp.float32),
    )


def test_scan_flops_equal_unrolled(structs):
    x, w = structs
    ts = account(jax.jit(_scanned).lower(x, w).compile().as_text())
    tu = account(jax.jit(_unrolled).lower(x, w).compile().as_text())
    assert ts.flops == pytest.approx(tu.flops, rel=1e-6)
    assert ts.flops == pytest.approx(2 * 8 * 128 * 256 * 256, rel=0.05)


def test_scan_grad_flops_equal_unrolled(structs):
    x, w = structs
    ts = account(jax.jit(jax.grad(_scanned)).lower(x, w).compile().as_text())
    tu = account(jax.jit(jax.grad(_unrolled)).lower(x, w).compile().as_text())
    assert ts.flops == pytest.approx(tu.flops, rel=1e-6)


def test_scan_bytes_within_factor_of_unrolled(structs):
    """Loop carries cost real extra traffic; the accountant must stay within
    a small factor of the unrolled module (was 3x+ before slice-aware
    charging)."""
    x, w = structs
    ts = account(jax.jit(jax.grad(_scanned)).lower(x, w).compile().as_text())
    tu = account(jax.jit(jax.grad(_unrolled)).lower(x, w).compile().as_text())
    assert ts.bytes < 2.5 * tu.bytes
    assert ts.bytes > 0.8 * tu.bytes


def test_nested_scan_multiplies():
    def inner(c, _):
        return jnp.tanh(c @ c), None

    def outer(c, _):
        c, _ = jax.lax.scan(inner, c, jnp.arange(4))
        return c, None

    def f(x):
        y, _ = jax.lax.scan(outer, x, jnp.arange(3))
        return y.sum()

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    t = account(jax.jit(f).lower(x).compile().as_text())
    assert t.flops == pytest.approx(3 * 4 * 2 * 64 ** 3, rel=0.05)


def test_roofline_report_terms():
    r = RooflineReport(flops=197e12, hbm_bytes=819e9, wire_bytes=50e9,
                       chips=4, model_flops_total=4 * 197e12 / 2)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(1.0)
    assert r.collective_s == pytest.approx(1.0)
    assert r.useful_flops_ratio == pytest.approx(0.5)
    assert r.dominant in ("compute", "memory", "collective")
