"""ServeEngine must run generation through the jitted partials it builds in
``__init__`` (regression: it used to call the unjitted ``lm.prefill`` /
``lm.decode_step`` module functions, leaving the jit dead)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import lm
from repro.serve import ServeEngine


@pytest.fixture(scope="module")
def engine_setup(request):
    tiny_dense = request.getfixturevalue("tiny_dense")
    cfg = lm.ModelCfg(dtype=jnp.float32, attn_impl="xla", ssm_impl="xla")
    params = lm.init_params(tiny_dense, jax.random.PRNGKey(0))
    return tiny_dense, cfg, params


def _prompts(vocab: int, batch: int = 2, length: int = 5) -> np.ndarray:
    return np.random.default_rng(0).integers(
        0, vocab, size=(batch, length)
    ).astype(np.int32)


def test_generate_uses_jitted_partials_not_module_functions(
    engine_setup, monkeypatch
):
    arch, cfg, params = engine_setup
    engine = ServeEngine(arch, cfg, params, max_len=16)

    def boom(*a, **kw):
        raise AssertionError(
            "generate must go through the jitted self._prefill/self._decode"
        )

    # the jitted partials captured lm.prefill/lm.decode_step at __init__;
    # poisoning the module attributes proves generate no longer reads them
    monkeypatch.setattr(lm, "prefill", boom)
    monkeypatch.setattr(lm, "decode_step", boom)

    result = engine.generate(_prompts(arch.vocab), max_new_tokens=3)
    assert result.tokens.shape == (2, 5 + 3)
    assert result.prompt_len == 5


def test_jitted_callables_are_exercised_and_compiled_once(engine_setup):
    arch, cfg, params = engine_setup
    engine = ServeEngine(arch, cfg, params, max_len=16)
    calls = {"prefill": 0, "decode": 0}
    real_prefill, real_decode = engine._prefill, engine._decode

    def spy_prefill(*a, **kw):
        calls["prefill"] += 1
        return real_prefill(*a, **kw)

    def spy_decode(*a, **kw):
        calls["decode"] += 1
        return real_decode(*a, **kw)

    engine._prefill, engine._decode = spy_prefill, spy_decode
    steps = 4
    engine.generate(_prompts(arch.vocab), max_new_tokens=steps)
    assert calls == {"prefill": 1, "decode": steps}
    # every decode step reuses one compiled executable (position is traced)
    assert real_decode._cache_size() == 1


def test_step_times_measure_each_decode_step(engine_setup):
    """generate records one positive wall-time per generated token — the
    raw material for a source="serve" calibration StepTrace."""
    arch, cfg, params = engine_setup
    engine = ServeEngine(arch, cfg, params, max_len=16)
    steps = 4
    result = engine.generate(_prompts(arch.vocab), max_new_tokens=steps)
    assert isinstance(result.step_times, tuple)
    assert len(result.step_times) == steps
    assert all(t > 0 for t in result.step_times)

    from repro.calibration.traces import StepTrace
    from repro.core.params import ParallelStrategy

    trace = StepTrace(
        arch=arch,
        strategy=ParallelStrategy(device="tpu-v5e", num_devices=1,
                                  micro_batch_size=2),
        global_batch=2, seq=5 + steps,
        step_times=result.step_times, source="serve",
    )
    text = trace.to_json()
    assert StepTrace.from_json(text).to_json() == text
    assert trace.measured_step_time > 0


def test_greedy_generation_is_deterministic(engine_setup):
    arch, cfg, params = engine_setup
    engine = ServeEngine(arch, cfg, params, max_len=16)
    prompts = _prompts(arch.vocab)
    a = engine.generate(prompts, max_new_tokens=4, temperature=0.0)
    b = engine.generate(prompts, max_new_tokens=4, temperature=0.0)
    np.testing.assert_array_equal(a.tokens, b.tokens)
    np.testing.assert_array_equal(a.tokens[:, :5], prompts)
