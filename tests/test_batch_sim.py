"""Batched evaluation engine: parity with the scalar reference simulator,
streaming top-k / Pareto equivalence, and the ZeRO overlap-discount fix."""
import dataclasses

import pytest

from repro.calibration.fit import AnalyticEtaModel
from repro.core import (
    Astra,
    CostSimulator,
    DeviceSweep,
    FixedPool,
    GpuConfig,
    HeteroCaps,
    HeteroPool,
    Limits,
    ObjectiveSpec,
    ParallelStrategy,
    SearchSpec,
    Workload,
)
from repro.core.batch import BatchedCostSimulator, _ParetoStaircase, _TopK
from repro.core.hetero import iter_hetero_strategies
from repro.core.memory import MemoryFilter
from repro.core.pareto import CostedStrategy, money_cost, optimal_pool, sort_strategies
from repro.core.search import generate_strategies

GB, SEQ = 512, 2048
REL = 1e-9


def _parity(arch, strategies, global_batch=GB, seq=SEQ):
    scalar = CostSimulator(AnalyticEtaModel())
    batched = BatchedCostSimulator(AnalyticEtaModel())
    r_b = batched.simulate_batch(arch, strategies, global_batch=global_batch, seq=seq)
    for s, rb in zip(strategies, r_b):
        ra = scalar.simulate(arch, s, global_batch=global_batch, seq=seq)
        assert rb.step_time == pytest.approx(ra.step_time, rel=REL), s
        assert rb.pipeline_time == pytest.approx(ra.pipeline_time, rel=REL), s
        assert rb.dp_exposed_time == pytest.approx(ra.dp_exposed_time, rel=REL, abs=1e-12), s
        assert rb.optimizer_time == pytest.approx(ra.optimizer_time, rel=REL), s
        assert rb.money_per_hour == pytest.approx(ra.money_per_hour, rel=REL), s
        assert len(rb.stage_times) == len(ra.stage_times)
        for a, b in zip(ra.stage_times, rb.stage_times):
            assert b == pytest.approx(a, rel=REL)
        for a, b in zip(ra.stage_p2p, rb.stage_p2p):
            assert b == pytest.approx(a, rel=REL, abs=1e-15)


def test_batched_matches_scalar_homogeneous_grid(llama7b):
    """Full funnel output for a mode-1 search cell: every strategy's step
    time must match the scalar reference to 1e-9 relative."""
    strategies, _ = generate_strategies(
        llama7b, [GpuConfig("A800", 64)], GB, SEQ
    )
    assert len(strategies) > 100
    _parity(llama7b, strategies[::7])  # sampled grid, keeps the test fast


def test_batched_matches_scalar_toggle_corners(llama7b):
    """Hand-picked corners: recompute, offload, ZeRO, overlap, vp, sp."""
    base = dict(device="A800", num_devices=64, tensor_parallel=2,
                pipeline_parallel=4, micro_batch_size=2)
    corners = [
        ParallelStrategy(**base),
        ParallelStrategy(**base, recompute_granularity="full", recompute_num_layers=4),
        ParallelStrategy(**base, recompute_granularity="selective"),
        ParallelStrategy(**base, use_distributed_optimizer=True,
                         overlap_grad_reduce=True),
        ParallelStrategy(**base, use_distributed_optimizer=True,
                         overlap_grad_reduce=True, overlap_param_gather=True),
        ParallelStrategy(**base, offload_optimizer=True),
        ParallelStrategy(**base, offload_optimizer=True, overlap_grad_reduce=True),
        ParallelStrategy(**base, sequence_parallel=True, tp_comm_overlap=True),
        ParallelStrategy(**base, virtual_pipeline_stages=2, overlap_p2p=False),
    ]
    _parity(llama7b, corners)


def test_batched_matches_scalar_mixed_device_types(llama7b):
    """Regression: one simulator instance across device types (the mode-3
    sweep) — cache keys must not collide between A800 and H100 strategies."""
    base = dict(num_devices=64, tensor_parallel=2, pipeline_parallel=2,
                micro_batch_size=1)
    strategies = [
        ParallelStrategy(device="A800", **base),
        ParallelStrategy(device="H100", **base),
        ParallelStrategy(device="A800", **base, sequence_parallel=True),
        ParallelStrategy(device="H100", **base, sequence_parallel=True),
    ]
    _parity(llama7b, strategies)
    # and through the streaming mode-3 facade: H100 must out-simulate A800
    batched = BatchedCostSimulator(AnalyticEtaModel())
    r = batched.simulate_batch(llama7b, strategies[:2], global_batch=GB, seq=SEQ)
    assert r[1].step_time < r[0].step_time


def test_batched_matches_scalar_hetero(llama7b):
    pool = HeteroPool(total_devices=32, type_caps=(("A800", 16), ("H100", 16)))
    mem = MemoryFilter(seq=SEQ)
    strategies = [
        s for s in iter_hetero_strategies(llama7b, pool, 128, fast=True)
        if mem.is_valid(llama7b, s)
    ]
    assert strategies, "hetero generator produced no memory-valid candidates"
    _parity(llama7b, strategies[:40], global_batch=128)


def test_streaming_topk_and_pool_match_batch_path(llama7b):
    strategies, _ = generate_strategies(
        llama7b, [GpuConfig("A800", 64)], GB, SEQ
    )
    strategies = strategies[::5]
    train_tokens = 1e9

    batched = BatchedCostSimulator(AnalyticEtaModel())
    sims = batched.simulate_batch(llama7b, strategies, global_batch=GB, seq=SEQ)
    costed = [
        CostedStrategy(strategy=s, sim=r, throughput=r.throughput_tokens,
                       money=money_cost(r, train_tokens))
        for s, r in zip(strategies, sims)
    ]
    ref_top = sort_strategies(costed)[:5]
    ref_pool = optimal_pool(costed)

    streaming = BatchedCostSimulator(AnalyticEtaModel())
    top, pool, n = streaming.evaluate_stream(
        llama7b, iter(strategies), global_batch=GB, seq=SEQ,
        train_tokens=train_tokens, top_k=5, chunk_size=64, keep_pool=True,
    )
    assert n == len(strategies)
    assert [(c.throughput, c.money) for c in top] == \
        [(c.throughput, c.money) for c in ref_top]
    assert [(c.throughput, c.money) for c in pool] == \
        [(c.throughput, c.money) for c in ref_pool]


def test_pareto_staircase_matches_optimal_pool(rng):
    """Randomized incremental-vs-batch Pareto equivalence, with ties."""
    def costed(p, c):
        return CostedStrategy(strategy=None, sim=None, throughput=p, money=c)

    for trial in range(25):
        pts = [
            costed(float(rng.integers(1, 12)), float(rng.integers(1, 12)))
            for _ in range(int(rng.integers(1, 40)))
        ]
        stair = _ParetoStaircase()
        for p in pts:
            stair.push(p)
        got = [(c.throughput, c.money) for c in stair.sorted()]
        want = [(c.throughput, c.money) for c in optimal_pool(pts)]
        assert got == want, (trial, pts)


def test_topk_matches_full_sort(rng):
    def costed(p, c):
        return CostedStrategy(strategy=None, sim=None, throughput=p, money=c)

    pts = [costed(float(rng.random()), float(rng.random())) for _ in range(200)]
    topk = _TopK(7)
    for p in pts:
        topk.push(p)
    got = [(c.throughput, c.money) for c in topk.sorted()]
    want = [(c.throughput, c.money) for c in sort_strategies(pts)[:7]]
    assert got == want


def test_zero_overlap_discount_differentiated(llama7b):
    """Regression for the dead conditional in stage_times: with ZeRO, the
    exposed gradient-communication time must depend on overlap_param_gather
    (only the reduce-scatter half overlaps without it)."""
    # small DP group + fat microbatch so the overlap is not clamped by the
    # available backward compute (hidden < t_bwd_comp)
    base = dict(device="A800", num_devices=8, tensor_parallel=2,
                pipeline_parallel=1, micro_batch_size=4,
                use_distributed_optimizer=True, overlap_grad_reduce=True)
    s_rs_only = ParallelStrategy(**base)
    s_both = ParallelStrategy(**base, overlap_param_gather=True)
    for sim in (CostSimulator(AnalyticEtaModel()),
                BatchedCostSimulator(AnalyticEtaModel())):
        r_rs = sim.simulate(llama7b, s_rs_only, global_batch=GB, seq=SEQ)
        r_both = sim.simulate(llama7b, s_both, global_batch=GB, seq=SEQ)
        assert r_both.dp_exposed_time < r_rs.dp_exposed_time, type(sim).__name__


def test_astra_batched_and_scalar_agree_end_to_end(llama7b):
    space = {
        "tensor_parallel": [2, 4],
        "pipeline_parallel": [2, 4],
        "micro_batch_size": [1, 2],
        "use_distributed_optimizer": [True],
        "recompute_granularity": ["none", "full"],
    }
    fast = Astra(AnalyticEtaModel(), use_batched=True)
    ref = Astra(AnalyticEtaModel(), use_batched=False)
    spec = SearchSpec(arch=llama7b, pool=FixedPool("A800", 64),
                      workload=Workload(GB, SEQ), space=space)
    r_fast = fast.search(spec)
    r_ref = ref.search(spec)
    assert r_fast.best == r_ref.best
    assert r_fast.best_sim.step_time == pytest.approx(
        r_ref.best_sim.step_time, rel=REL
    )
    assert [c.strategy for c in r_fast.top] == [c.strategy for c in r_ref.top]


def test_cache_trim_across_batches(llama7b, monkeypatch):
    """Regression: overflowing the stage caches between batches must trim
    cleanly — a mid-batch clear used to drop keys the batch still needed."""
    import repro.core.batch as batch_mod

    monkeypatch.setattr(batch_mod, "_STAGE_CACHE_MAX", 4)
    strategies, _ = generate_strategies(
        llama7b, [GpuConfig("A800", 64)], GB, SEQ
    )
    strategies = strategies[:60]
    sim = BatchedCostSimulator(AnalyticEtaModel())
    ref = BatchedCostSimulator(AnalyticEtaModel())
    expect = ref.simulate_batch(llama7b, strategies, global_batch=GB, seq=SEQ)
    # many small batches against the same simulator force repeated trims
    got = []
    for i in range(0, len(strategies), 7):
        got.extend(
            sim.simulate_batch(
                llama7b, strategies[i:i + 7], global_batch=GB, seq=SEQ
            )
        )
    for a, b in zip(expect, got):
        assert b.step_time == pytest.approx(a.step_time, rel=REL)


def test_op_table_trim_across_batches(llama7b, monkeypatch):
    """The persistent op->time tables must stay bounded across batches (a
    long-lived search service) without changing results."""
    import repro.core.batch as batch_mod

    monkeypatch.setattr(batch_mod, "_OP_TABLE_MAX", 8)
    strategies, _ = generate_strategies(
        llama7b, [GpuConfig("A800", 64)], GB, SEQ
    )
    strategies = strategies[:40]
    sim = BatchedCostSimulator(AnalyticEtaModel())
    ref = BatchedCostSimulator(AnalyticEtaModel())
    expect = ref.simulate_batch(llama7b, strategies, global_batch=GB, seq=SEQ)
    got = []
    for i in range(0, len(strategies), 5):
        got.extend(
            sim.simulate_batch(
                llama7b, strategies[i:i + 5], global_batch=GB, seq=SEQ
            )
        )
    for a, b in zip(expect, got):
        assert b.step_time == pytest.approx(a.step_time, rel=REL)
    # the trim actually fired: the chunked run's tables hold only the ops
    # resolved since the last trim, not the whole search's distinct-op set
    assert len(sim._comp.index) < len(ref._comp.index)


def _toggle_corners(pp=4):
    base = dict(device="A800", num_devices=64, tensor_parallel=2,
                pipeline_parallel=pp, micro_batch_size=2)
    return [
        ParallelStrategy(**base),
        ParallelStrategy(**base, recompute_granularity="full",
                         recompute_num_layers=4),
        ParallelStrategy(**base, use_distributed_optimizer=True,
                         overlap_grad_reduce=True),
        ParallelStrategy(**base, use_distributed_optimizer=True,
                         overlap_grad_reduce=True, overlap_param_gather=True),
        ParallelStrategy(**base, offload_optimizer=True),
        ParallelStrategy(**base, offload_optimizer=True,
                         overlap_grad_reduce=True),
        ParallelStrategy(**base, sequence_parallel=True, tp_comm_overlap=True),
        ParallelStrategy(**base, virtual_pipeline_stages=2, overlap_p2p=False),
    ]


def test_finalize_pending_matches_scalar_finalize_exactly(llama7b):
    """The vectorized overlap/offload pass must equal the scalar
    `_finalize_stage` reference bit-for-bit on every toggle corner."""
    recorded = {}

    class Recording(BatchedCostSimulator):
        def _finalize_pending(self, pending_time):
            recorded.update(pending_time)
            super()._finalize_pending(pending_time)

    sim = Recording(AnalyticEtaModel())
    sim.simulate_batch(llama7b, _toggle_corners(), global_batch=GB, seq=SEQ)
    assert recorded, "no timing keys were pending"
    for tkey, (ckey, s) in recorded.items():
        want = sim._finalize_stage(sim._raw_cache[ckey], s)
        assert sim._stage_time_cache[tkey] == want, (tkey, s)


def test_compose_batch_matches_scalar_compose(llama7b):
    """The chunk-wide Eq. 22 array pass against the scalar
    `compose_sim_result` reference on the same stage tuples: the
    max-reductions and per-stage lists are bit-identical; the segment sums
    (numpy pairwise vs Python left-to-right) agree to 1e-12 relative —
    far inside the file's 1e-9 engine-parity contract."""
    import dataclasses as _dc

    from repro.core.simulate import compose_sim_result

    strategies = _toggle_corners(pp=4) + _toggle_corners(pp=8)
    sim = BatchedCostSimulator(AnalyticEtaModel())
    got = sim.simulate_batch(llama7b, strategies, global_batch=GB, seq=SEQ)
    for s, r in zip(strategies, got):
        plan = sim._stage_plan(llama7b, s, SEQ)
        per_stage = [sim._stage_time_cache[t] for t, _, _, _, _ in plan]
        ref = compose_sim_result(s, per_stage, global_batch=GB, seq=SEQ)
        # max-reductions and the per-stage vectors carry no summation: exact
        assert r.stage_times == ref.stage_times, s
        assert r.stage_p2p == ref.stage_p2p, s
        assert r.dp_exposed_time == ref.dp_exposed_time, s
        assert r.optimizer_time == ref.optimizer_time, s
        assert r.money_per_hour == ref.money_per_hour, s
        for f in _dc.fields(ref):
            a, b = getattr(ref, f.name), getattr(r, f.name)
            if isinstance(a, float):
                assert b == pytest.approx(a, rel=1e-12), (f.name, s)


def test_mode2_counts_are_honest(llama7b):
    astra = Astra(AnalyticEtaModel())
    pool = HeteroPool(total_devices=32, type_caps=(("A800", 16), ("H100", 16)))
    rep = astra.search(SearchSpec(
        arch=llama7b, pool=HeteroCaps.of(pool, prune_slack=None),
        workload=Workload(128, SEQ),
    ))
    c = rep.counts
    assert c.generated == c.divisible  # divisible by construction
    assert c.generated >= c.after_rules >= c.after_memory > 0
    assert rep.best is not None


def test_mode3_streaming_pool_and_budget(llama7b):
    astra = Astra(AnalyticEtaModel())
    rep = astra.search(SearchSpec(
        arch=llama7b, pool=DeviceSweep(("A800", "H100"), 64),
        workload=Workload(GB, SEQ), objective=ObjectiveSpec.pareto(None),
        limits=Limits(top_k=3),
    ))
    assert rep.best is not None
    assert rep.pool, "mode-3 must return a non-empty Pareto pool"
    # pool is non-dominated and sorted by throughput desc
    thr = [c.throughput for c in rep.pool]
    assert thr == sorted(thr, reverse=True)
    for a in rep.pool:
        assert not any(
            b.throughput > a.throughput and b.money < a.money for b in rep.pool
        )
    # the unlimited-budget pick is the throughput argmax of the pool
    assert rep.best_sim.throughput_tokens == pytest.approx(max(thr))
