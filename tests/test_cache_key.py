"""Spec canonicalization: `cache_key()` must be invariant under every
non-semantic rewrite of the spec JSON (key order, explicit nulls, omitted
default sections, numeric spelling) and must change under every semantic
one (pool, workload, objective, limits)."""
import dataclasses
import itertools
import json
import random

from repro.core import (
    DeviceSweep,
    FixedPool,
    HeteroCaps,
    Limits,
    ObjectiveSpec,
    SearchSpec,
    Workload,
)


def _spec(llama7b, **over) -> SearchSpec:
    kw = dict(
        arch=llama7b,
        pool=HeteroCaps(32, (("A800", 16), ("H100", 16)), prune_slack=1.5),
        workload=Workload(128, 2048, train_tokens=2e9),
        objective=ObjectiveSpec.pareto(80.0),
        limits=Limits(top_k=5),
    )
    kw.update(over)
    return SearchSpec(**kw)


def _shuffle(value, rng):
    """Recursively rebuild dicts with randomized key insertion order."""
    if isinstance(value, dict):
        items = list(value.items())
        rng.shuffle(items)
        return {k: _shuffle(v, rng) for k, v in items}
    if isinstance(value, list):
        return [_shuffle(v, rng) for v in value]
    return value


# ---------------------------------------------------------------------------
# invariance (property-style: many random permutations, several seeds)
# ---------------------------------------------------------------------------

def test_key_order_permutations_share_one_key(llama7b):
    spec = _spec(llama7b)
    key = spec.cache_key()
    base = json.loads(spec.to_json())
    for seed in range(20):
        rng = random.Random(seed)
        text = json.dumps(_shuffle(base, rng))
        assert SearchSpec.from_json(text).cache_key() == key


def test_top_level_key_permutations_share_one_key(llama7b):
    spec = _spec(llama7b)
    key = spec.cache_key()
    base = json.loads(spec.to_json())
    for perm in itertools.islice(itertools.permutations(base), 24):
        text = json.dumps({k: base[k] for k in perm})
        assert SearchSpec.from_json(text).cache_key() == key


def test_omitted_defaults_and_explicit_nulls_share_one_key(llama7b):
    spec = SearchSpec(
        arch=llama7b, pool=FixedPool("A800", 64), workload=Workload(128, 2048)
    )
    key = spec.cache_key()
    d = json.loads(spec.to_json())

    minimal = {k: v for k, v in d.items()
               if k in ("version", "arch", "pool", "workload")}
    assert SearchSpec.from_json(json.dumps(minimal)).cache_key() == key

    padded = dict(d)
    padded["space"] = None
    padded["hetero_base"] = None
    padded["objective"] = {"kind": "throughput", "budget": None,
                           "slo_seconds": None}
    padded["limits"] = {"top_k": 5, "chunk_size": None, "max_candidates": None}
    assert SearchSpec.from_json(json.dumps(padded)).cache_key() == key


def test_numeric_spelling_is_normalized(llama7b):
    a = _spec(llama7b, workload=Workload(128, 2048, train_tokens=2e9))
    b_text = a.to_json().replace("2000000000.0", "2000000000")
    b = SearchSpec.from_json(b_text)
    assert isinstance(b.workload.train_tokens, int)  # actually re-spelled
    assert b.cache_key() == a.cache_key()


def test_equal_specs_equal_keys_all_pool_shapes(llama7b):
    for pool in (
        FixedPool("A800", 64),
        HeteroCaps(32, (("A800", 16), ("H100", 16))),
        DeviceSweep(("A800", "H100"), 128),
    ):
        s1 = _spec(llama7b, pool=pool)
        s2 = SearchSpec.from_json(s1.to_json())
        assert s1 == s2
        assert s1.cache_key() == s2.cache_key()
        assert len(s1.cache_key()) == 64  # sha256 hexdigest


# ---------------------------------------------------------------------------
# sensitivity: every semantic change moves the key
# ---------------------------------------------------------------------------

def test_semantic_changes_change_the_key(llama7b):
    base = _spec(llama7b)
    variants = {
        "base": base,
        "pool-count": _spec(llama7b, pool=HeteroCaps(
            64, (("A800", 32), ("H100", 32)), prune_slack=1.5)),
        "pool-caps": _spec(llama7b, pool=HeteroCaps(
            32, (("A800", 8), ("H100", 24)), prune_slack=1.5)),
        "pool-shape": _spec(llama7b, pool=FixedPool("A800", 32)),
        "pool-prune": _spec(llama7b, pool=HeteroCaps(
            32, (("A800", 16), ("H100", 16)), prune_slack=None)),
        "workload-batch": _spec(llama7b, workload=Workload(256, 2048, 2e9)),
        "workload-seq": _spec(llama7b, workload=Workload(128, 4096, 2e9)),
        "workload-tokens": _spec(llama7b, workload=Workload(128, 2048, 1e9)),
        "objective-kind": _spec(llama7b, objective=ObjectiveSpec.money(80.0)),
        "objective-budget": _spec(llama7b, objective=ObjectiveSpec.pareto(81.0)),
        "objective-slo": _spec(llama7b, objective=ObjectiveSpec.latency(1.5)),
        "limits-topk": _spec(llama7b, limits=Limits(top_k=9)),
        "limits-cap": _spec(llama7b, limits=Limits(max_candidates=100)),
        "space": _spec(llama7b, space={"tensor_parallel": [1, 2]}),
        "hetero-base": _spec(llama7b, hetero_base={"use_flash_attn": True}),
        "arch": _spec(llama7b, arch=dataclasses.replace(llama7b, num_layers=16)),
    }
    keys = {name: s.cache_key() for name, s in variants.items()}
    assert len(set(keys.values())) == len(keys), keys


def test_type_caps_order_is_semantic(llama7b):
    """Pipeline order of hetero type caps is meaningful (contiguous-segment
    placement), so swapping it must NOT collide."""
    a = _spec(llama7b, pool=HeteroCaps(32, (("A800", 16), ("H100", 16))))
    b = _spec(llama7b, pool=HeteroCaps(32, (("H100", 16), ("A800", 16))))
    assert a.cache_key() != b.cache_key()


def test_canonical_json_is_deterministic(llama7b):
    spec = _spec(llama7b)
    assert spec.canonical_json() == spec.canonical_json()
    text = spec.canonical_json()
    assert "null" not in text  # no-op defaults are dropped
    assert json.loads(text) == spec.canonicalize()
