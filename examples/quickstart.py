"""Quickstart: the paper's three search modes in one minute.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.calibration.fit import load_or_train
from repro.core import Astra, HeteroPool, ModelArch

# a model architecture (Eq. 5-6) — here llama2-7b, or build your own
llama7b = ModelArch(name="llama2-7b", family="dense", num_layers=32,
                    hidden=4096, heads=32, kv_heads=32, ffn=11008, vocab=32000)

eta, report = load_or_train()  # the XGBoost-style eta cost model (cached)
if report:
    print(f"calibrated eta model: {report}")
astra = Astra(eta)

# ---- mode 1: homogeneous — fixed device type and count --------------------
rep = astra.search_homogeneous(llama7b, "A800", 64, global_batch=512, seq=4096)
b = rep.best
print(f"\n[mode 1] A800 x64: searched {rep.counts.generated} strategies "
      f"({rep.counts.after_memory} feasible) in {rep.e2e_seconds:.2f}s")
print(f"  best: tp={b.tensor_parallel} pp={b.pipeline_parallel} dp={b.data_parallel} "
      f"mbs={b.micro_batch_size} sp={b.sequence_parallel} "
      f"dist_opt={b.use_distributed_optimizer} recompute={b.recompute_granularity}")
print(f"  simulated: {rep.best_sim.throughput_tokens:,.0f} tokens/s, "
      f"step {rep.best_sim.step_time:.2f}s")

# ---- mode 2: heterogeneous — mixed A800 + H100 cluster ---------------------
pool = HeteroPool(total_devices=64, type_caps=(("A800", 32), ("H100", 32)))
rep2 = astra.search_heterogeneous(llama7b, pool, global_batch=512, seq=4096)
b2, pl = rep2.best, rep2.best.hetero
print(f"\n[mode 2] A800+H100 x64: {rep2.counts.generated} placements in "
      f"{rep2.e2e_seconds:.2f}s")
print(f"  best: tp={b2.tensor_parallel} pp={b2.pipeline_parallel} "
      f"stages={list(zip(pl.devices, pl.stages_per_type, pl.layers_per_stage))}")
print(f"  simulated: {rep2.best_sim.throughput_tokens:,.0f} tokens/s")

# ---- mode 3: cost — best plan under a money limit ---------------------------
rep3 = astra.search_cost(llama7b, ["H100", "A800"], 512, global_batch=512,
                         seq=4096, money_limit=80.0, train_tokens=1e9)
print(f"\n[mode 3] <=512 GPUs, $80 budget for 1B tokens: pareto pool size "
      f"{len(rep3.pool)}")
for c in rep3.pool[:5]:
    print(f"  {c.strategy.device} x{c.strategy.num_devices}: "
          f"{c.throughput:,.0f} tok/s, ${c.money:.2f}")
b3 = rep3.best
print(f"  picked: {b3.device} x{b3.num_devices} "
      f"(tp={b3.tensor_parallel}, pp={b3.pipeline_parallel})")
