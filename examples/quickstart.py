"""Quickstart: one declarative SearchSpec pipeline, three pool shapes.

    PYTHONPATH=src python examples/quickstart.py

Every Astra search is a ``SearchSpec``: the model arch, a GPU pool (one of
three shapes — this is what used to be the "three modes"), the workload,
and an objective. Specs are plain data and round-trip through JSON, so the
exact same search can be shipped to a service and replayed.
"""
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.calibration.fit import load_or_train
from repro.core import (
    Astra,
    DeviceSweep,
    FixedPool,
    HeteroCaps,
    ModelArch,
    ObjectiveSpec,
    SearchSpec,
    Workload,
)

# a model architecture (Eq. 5-6) — here llama2-7b, or build your own
llama7b = ModelArch(name="llama2-7b", family="dense", num_layers=32,
                    hidden=4096, heads=32, kv_heads=32, ffn=11008, vocab=32000)

eta, report = load_or_train()  # the XGBoost-style eta cost model (cached)
if report:
    print(f"calibrated eta model: {report}")
astra = Astra(eta)
workload = Workload(global_batch=512, seq=4096, train_tokens=1e9)

# ---- fixed pool (the old mode 1): one device type at a fixed count --------
rep = astra.search(SearchSpec(
    arch=llama7b,
    pool=FixedPool("A800", 64),
    workload=workload,
))
b = rep.best
print(f"\n[fixed pool] A800 x64: searched {rep.counts.generated} strategies "
      f"({rep.counts.after_memory} feasible) in {rep.e2e_seconds:.2f}s")
print(f"  best: tp={b.tensor_parallel} pp={b.pipeline_parallel} dp={b.data_parallel} "
      f"mbs={b.micro_batch_size} sp={b.sequence_parallel} "
      f"dist_opt={b.use_distributed_optimizer} recompute={b.recompute_granularity}")
print(f"  simulated: {rep.best_sim.throughput_tokens:,.0f} tokens/s, "
      f"step {rep.best_sim.step_time:.2f}s")

# ---- hetero caps (the old mode 2): mixed A800 + H100 cluster --------------
rep2 = astra.search(SearchSpec(
    arch=llama7b,
    pool=HeteroCaps(total_devices=64, type_caps=(("A800", 32), ("H100", 32))),
    workload=workload,
))
b2, pl = rep2.best, rep2.best.hetero
print(f"\n[hetero caps] A800+H100 x64: {rep2.counts.generated} placements in "
      f"{rep2.e2e_seconds:.2f}s")
print(f"  best: tp={b2.tensor_parallel} pp={b2.pipeline_parallel} "
      f"stages={list(zip(pl.devices, pl.stages_per_type, pl.layers_per_stage))}")
print(f"  simulated: {rep2.best_sim.throughput_tokens:,.0f} tokens/s")

# ---- device sweep + pareto objective (the old mode 3): money limit --------
spec3 = SearchSpec(
    arch=llama7b,
    pool=DeviceSweep(devices=("H100", "A800"), max_devices=512),
    workload=workload,
    objective=ObjectiveSpec.pareto(budget=80.0),
)
# specs serialize — ship this search to a service and replay it verbatim:
spec3 = SearchSpec.from_json(spec3.to_json())
rep3 = astra.search(spec3)
print(f"\n[sweep+pareto] <=512 GPUs, $80 budget for 1B tokens: pareto pool "
      f"size {len(rep3.pool)}")
for c in rep3.pool[:5]:
    print(f"  {c.strategy.device} x{c.strategy.num_devices}: "
          f"{c.throughput:,.0f} tok/s, ${c.money:.2f}")
b3 = rep3.best
print(f"  picked: {b3.device} x{b3.num_devices} "
      f"(tp={b3.tensor_parallel}, pp={b3.pipeline_parallel})")

# ---- new objective for free: cheapest plan that still trains the budget ---
cheap = astra.search(SearchSpec(
    arch=llama7b,
    pool=DeviceSweep(devices=("H100", "A800"), max_devices=512),
    workload=workload,
    objective=ObjectiveSpec.money(),
))
cb = cheap.best
print(f"\n[sweep+money] cheapest plan: {cb.device} x{cb.num_devices} at "
      f"${cheap.top[0].money:.2f} per 1B tokens "
      f"({cheap.top[0].throughput:,.0f} tok/s)")

# ---- latency SLO: cheapest plan whose step time meets the deadline --------
slo = rep.best_sim.step_time * 1.5  # give the scheduler 50% headroom
slo_rep = astra.search(SearchSpec(
    arch=llama7b,
    pool=FixedPool("A800", 64),
    workload=workload,
    objective=ObjectiveSpec.latency(slo_seconds=slo),
))
sb = slo_rep.best
print(f"\n[latency slo] step <= {slo:.2f}s: "
      f"tp={sb.tensor_parallel} pp={sb.pipeline_parallel} "
      f"step {slo_rep.best_sim.step_time:.2f}s, "
      f"${slo_rep.top[0].money:.2f} per 1B tokens")

# ---- the service flow: spec -> POST -> cached report ----------------------
# Both ends of the pipeline are wire formats. A spec has a canonical
# identity — insensitive to JSON key order and no-op defaults — that a
# result cache keys on:
spec = SearchSpec(arch=llama7b, pool=FixedPool("A800", 64), workload=workload)
print(f"\n[service] spec cache key: {spec.cache_key()[:16]}...")

# SearchService wraps Astra with that cache (plus single-flight dedup of
# concurrent identical specs). Every report it returns passed through
# SearchReport.to_json/from_json — the serialized path is the only path:
from repro.serve import SearchService

service = SearchService(astra)
r_cold = service.search(spec)  # runs the search, caches the report JSON
r_warm = service.search(spec)  # served from cache, bit-identical
assert r_warm == r_cold
print(f"[service] warm hit == cold report; "
      f"stats: {service.stats_dict()['hits']} hit / "
      f"{service.stats_dict()['misses']} miss")

# The same service speaks HTTP (see examples/README.md for the contract):
#     python -m repro.serve.search_service serve --port 8123
#     python -m repro.serve.search_service search \
#         --url http://localhost:8123 --spec spec.json
# and a serving host deploys the strategy it answers with:
#     python examples/serve_batched.py --search-spec spec.json \
#         --search-url http://localhost:8123
#
# In production the cache is durable and shared: `--store sqlite:reports.db`
# makes reports survive restarts and be served warm by every replica on the
# file, and `--auth-tokens tokens.txt` turns on bearer-token auth with
# per-token request/cold-search quotas (401/429, token-bucket rate limits).
# See examples/README.md §Persistence and §Auth for the store URL and
# token-file formats.
#
# Big searches parallelize: Limits(workers=N) shards every candidate
# stream over N workers (0 = one per core) and merges the collectors —
# the report is byte-identical to the serial one, and `workers` is
# dropped from the spec's cache_key(), so parallel and serial searches of
# one spec share a cache entry. The serve CLI can pin it fleet-side
# (`serve --search-workers 0`) and runs cold searches of distinct specs
# concurrently (`--search-concurrency`). E.g.:
#     rep = astra.search(SearchSpec(arch=llama7b,
#                                   pool=DeviceSweep(("A800", "H100"), 512),
#                                   workload=workload,
#                                   limits=Limits(workers=0)))

# ---- fleet search: the same shards, dealt to workers over HTTP ------------
# Every service is already a fleet worker (POST /v1/shard). Here: a
# two-worker fleet on localhost, driven by Limits(fleet=...) — in
# production the workers are other hosts and the coordinator is
# `serve --fleet http://w1:8123,http://w2:8123`.
import threading

from repro.core import Limits
from repro.serve.search_service import make_server

servers = [make_server(SearchService(Astra(eta)), port=0) for _ in range(2)]
for s in servers:
    threading.Thread(target=s.serve_forever, daemon=True).start()
urls = tuple(f"http://127.0.0.1:{s.server_address[1]}" for s in servers)

fleet_spec = SearchSpec(
    arch=llama7b,
    pool=HeteroCaps(total_devices=16, type_caps=(("A800", 8), ("H100", 8))),
    workload=workload,
    limits=Limits(fleet=urls),
)
fleet_rep = Astra(eta).search(fleet_spec)
serial_rep = Astra(eta).search(SearchSpec(
    arch=fleet_spec.arch, pool=fleet_spec.pool, workload=fleet_spec.workload,
))
assert fleet_rep.normalized_json() == serial_rep.normalized_json()
for s in servers:
    s.shutdown()
# fleet, like workers, is an execution detail: same cache key either way
assert fleet_spec.cache_key() == dataclasses.replace(
    fleet_spec, limits=Limits()
).cache_key()
print(f"\n[fleet] 2-worker fleet searched {fleet_rep.counts.generated} "
      f"placements; report byte-identical to serial, same cache key")

# ---- calibration loop: measured traces -> refit -> re-search --------------
# Reports are stamped with the content-hash version of the eta model that
# ranked them. A calibrating service ingests measured StepTraces, scores
# them against the live model, refits when rolling accuracy decays, and
# re-searches stale reports on demand (POST /v1/search?refresh=stale).
from repro.calibration import (
    CalibrationLoop,
    GroundTruth,
    replay_profile,
    simulate_step_trace,
)

loop = CalibrationLoop(eta, threshold=0.95, min_traces=3,
                       min_refit_samples=50, refit_estimators=60)
cal_service = SearchService(Astra(eta), calibration=loop)
v1 = loop.version
r1 = cal_service.search(spec)
print(f"\n[calibration] report stamped eta_model_version={r1.eta_model_version}")

# stand-in for a real cluster drifting: the ground truth with derated
# compute/comm efficiency. launch/train.py --emit-traces produces the same
# wire documents from real measured step times.
drifted = GroundTruth(jitter_sigma=0.0, base_eff_scale=0.6, comm_eff_scale=0.8)
for seed in range(4):
    comp, comm = replay_profile(drifted, n_compute=60, n_comm=60, seed=seed)
    trace = simulate_step_trace(drifted, llama7b, r1.best,
                                global_batch=512, seq=4096,
                                compute_samples=comp, comm_samples=comm)
    ack = cal_service.ingest_trace_json(trace.to_json())  # POST /v1/traces
    print(f"[calibration] trace accuracy {ack['accuracy']:.3f} "
          f"(rolling {ack['rolling_accuracy']:.3f})"
          + (f" -> REFIT {ack['new_version']}" if ack["refit"] else ""))

# the cached report is now stale (ranked by v1); refresh=stale re-searches
# it under the refitted model and the new report is stamped accordingly
_, text, cached = cal_service.search_json(spec.to_json(), refresh_stale=True)
import json as _json

print(f"[calibration] {v1} -> {loop.version}; refreshed report stamped "
      f"{_json.loads(text)['eta_model_version']} (cached={cached}); "
      f"registry holds {len(loop.registry)} model versions")

# ---- fleet planner: many jobs, heterogeneous pools, one plan --------------
# One level up: a FleetSpec names GPU pools (capacity, optional price
# override / grid carbon intensity) and a queue of prioritized workloads;
# POST /v1/plan (or service.plan) batch-searches the workload x pool grid
# through the same spec-keyed cache and assigns jobs to pools under the
# fleet objective — here throughput-per-dollar, the paper's money-saving
# mode at fleet scale.
from repro.fleet import FleetObjective, FleetSpec, FleetWorkload, GpuPool

fleet = FleetSpec(
    pools=(
        GpuPool("a800-reserved", "A800", 16),
        GpuPool("h100-spot", "H100", 8, price_per_hour=3.50),  # spot discount
    ),
    workloads=(
        FleetWorkload("chat-7b", llama7b, 512, 4096, priority=2),
        FleetWorkload("ablate-7b", llama7b, 256, 4096),
        FleetWorkload("long-ctx-7b", llama7b, 128, 8192),
    ),
    objective=FleetObjective.throughput_per_dollar(),
)
fleet_plan = service.plan(fleet)  # cold: searches the 6-cell grid
print(f"\n[planner] solver={fleet_plan.solver}, "
      f"{fleet_plan.total_throughput:,.0f} tok/s aggregate at "
      f"${fleet_plan.total_dollars_per_hour:.2f}/hr "
      f"({fleet_plan.throughput_per_dollar:,.0f} tok/s per $/hr)")
for a in fleet_plan.assignments:
    print(f"  {a.workload}: {a.pool} x{a.devices} "
          f"(tp={a.choice.strategy.tensor_parallel} "
          f"pp={a.choice.strategy.pipeline_parallel}) "
          f"{a.throughput:,.0f} tok/s, ${a.dollars_per_hour:.2f}/hr")
for pu in fleet_plan.pools:
    print(f"  pool {pu.pool}: {pu.used}/{pu.capacity} devices "
          f"({pu.leftover} left)")

# plans are wire formats cached under FleetSpec.cache_key() (insensitive
# to pool/workload order); a re-plan rides the warm grid — zero searches
replan = service.plan(fleet)
assert replan.to_json() == fleet_plan.to_json()
s = service.stats_dict()
print(f"[planner] warm re-plan byte-identical; grid cells {s['grid_cells']}, "
      f"warm {s['grid_warm_hits']}, plans {s['plans']}")

# ---- serving workloads + elastic re-search --------------------------------
# A Workload with an InferenceShape searches a *deployment* instead of a
# training run: the cost model scores one dense prefill plus per-token
# decode steps (KV-cache-bound), and a latency objective picks the
# cheapest plan meeting the per-token SLO.
from repro.core import InferenceShape

serving = SearchSpec(
    arch=llama7b,
    pool=DeviceSweep(("A800", "H100"), max_devices=64),
    workload=Workload(global_batch=64, seq=4096, inference=InferenceShape(
        prefill_len=512, decode_len=128, slo_per_token=0.05,
    )),
    objective=ObjectiveSpec.latency(),  # SLO defaults to slo_per_token
)
srv_rep = service.search(serving)
sb = srv_rep.best
print(f"\n[serving] <=64 GPUs, 50ms/token SLO: {sb.device} x{sb.num_devices} "
      f"(tp={sb.tensor_parallel} pp={sb.pipeline_parallel}), "
      f"{srv_rep.best_sim.step_time * 1e3:.1f} ms/token, "
      f"TTFT {srv_rep.best_sim.pipeline_time * 1e3:.0f} ms")

# the pool shrinks (half the sweep is gone): ?elastic=1 warm-starts from
# the prior report of the same search *family* (the spec minus its pool) —
# prior winners re-simulate, only newly-feasible cells stream, and the
# funnel counters prove the saving
shrunk = dataclasses.replace(serving, pool=DeviceSweep(("A800", "H100"), 32))
_, text, _ = service.search_json(shrunk.to_json(), elastic=True)
er = _json.loads(text)
print(f"[elastic] pool 64 -> 32: re-searched with {er['evaluated']} "
      f"evaluations (cold was {srv_rep.evaluated}); "
      f"warm starts: {service.stats_dict()['elastic_warm_starts']}")
