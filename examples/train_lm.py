"""End-to-end training driver: Astra-searched strategy -> real training run.

The production invocation (a ~110M-param qwen3-family model, a few hundred
steps — what you would run on a v5e slice; on this CPU container it takes
hours):

    PYTHONPATH=src python examples/train_lm.py --size 100m --steps 300

The CPU-friendly demo (~15M params, ~5 minutes, loss visibly descends to
the synthetic corpus' entropy floor):

    PYTHONPATH=src python examples/train_lm.py --size 15m --steps 200
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.arch import ModelArch
from repro.launch import train as train_mod

SIZES = {
    # ~110M: 12L x 768d (GPT-2-small-ish with SwiGLU + GQA)
    "100m": ModelArch(name="lm-100m", family="dense", num_layers=12, hidden=768,
                      heads=12, kv_heads=4, ffn=3072, vocab=32000),
    # ~15M: CPU-demo scale
    "15m": ModelArch(name="lm-15m", family="dense", num_layers=6, hidden=384,
                     heads=6, kv_heads=2, ffn=1536, vocab=4096),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", choices=list(SIZES), default="15m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_train_ckpt")
    args = ap.parse_args()

    arch = SIZES[args.size]
    print(f"training {arch.name}: {arch.total_params()/1e6:.1f}M params")

    # reuse the production driver with an explicit arch (register in place so
    # every module-level reference sees it)
    import repro.configs as configs

    configs.PAPER_MODELS[arch.name] = arch
    train_mod.main([
        "--arch", arch.name,
        "--steps", str(args.steps),
        "--batch", str(args.batch),
        "--seq", str(args.seq),
        "--lr", "3e-3",
        "--checkpoint-dir", args.checkpoint_dir,
        "--checkpoint-every", "50",
        "--log-every", "10",
    ])


if __name__ == "__main__":
    main()
