"""Batched serving demo: prefill + decode over the ServeEngine.

    PYTHONPATH=src python examples/serve_batched.py --arch qwen3-8b --tokens 24
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.models import lm
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    arch = get_reduced(args.arch)
    cfg = lm.ModelCfg(dtype=jnp.float32, attn_impl="xla", ssm_impl="xla")
    params = lm.init_params(arch, jax.random.PRNGKey(0))
    engine = ServeEngine(arch, cfg, params,
                         max_len=args.prompt_len + args.tokens + 8)

    prompts = np.random.default_rng(0).integers(
        0, arch.vocab, size=(args.batch, args.prompt_len)
    ).astype(np.int32)

    t0 = time.time()
    result = engine.generate(prompts, max_new_tokens=args.tokens,
                             temperature=args.temperature, seed=1)
    dt = time.time() - t0
    total_new = args.batch * args.tokens
    print(f"arch={arch.name} ({arch.total_params()/1e6:.1f}M params, "
          f"family={arch.family})")
    print(f"batched generate: {args.batch} requests x {args.tokens} tokens "
          f"in {dt:.2f}s ({total_new/dt:.1f} tok/s incl. compile)")
    for i, row in enumerate(result.tokens[:2]):
        print(f"  req{i}: prompt={row[:args.prompt_len].tolist()[:8]}... "
              f"generated={row[args.prompt_len:].tolist()}")


if __name__ == "__main__":
    main()
