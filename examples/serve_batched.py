"""Batched serving demo: prefill + decode over the ServeEngine.

    PYTHONPATH=src python examples/serve_batched.py --arch qwen3-8b --tokens 24

With ``--search-spec spec.json`` the server first replays a serialized
:class:`repro.core.SearchSpec` through the spec-keyed
:class:`repro.serve.SearchService` and reports the strategy it would deploy
— both the spec and the report are wire formats (see examples/README.md for
the endpoint contract), so the replayed report is exactly what a control
plane would have served. Pass ``--search-url http://host:port`` to fetch
the report from a remote service (``python -m repro.serve.search_service
serve``) instead of searching in-process; repeated deploys of the same spec
then hit the fleet-wide cache.
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.models import lm
from repro.serve import ServeEngine


def pick_strategy_from_spec(path: str, url: str = None, token: str = None,
                            timeout: float = None):
    """Replay a serialized SearchSpec through the search service.

    In-process by default; with ``url`` the spec is POSTed to a remote
    service (``token`` authenticates against an ``--auth-tokens`` service)
    through the hardened HTTP client: a dead service fails within
    ``timeout`` with a clean error instead of hanging the deploy forever,
    and transient transport faults retry with backoff."""
    from repro.core import SearchSpec

    with open(path) as f:
        spec_json = f.read()
    spec = SearchSpec.from_json(spec_json)

    if url:
        from repro.serve.search_service import post_spec

        kw = {} if timeout is None else {"timeout": timeout}
        key, report, cached = post_spec(url, spec_json, token=token, **kw)
        print(f"served by {url} (key={key} cached={cached})")
        return spec, report

    from repro.calibration.fit import load_or_train
    from repro.core import Astra
    from repro.serve import SearchService

    eta, _ = load_or_train()
    return spec, SearchService(Astra(eta)).search(spec)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--search-spec", default=None, metavar="SPEC_JSON",
                    help="replay a serialized SearchSpec and report the "
                         "strategy this deployment would use")
    ap.add_argument("--search-url", default=None, metavar="URL",
                    help="fetch the report from a running search service "
                         "instead of searching in-process")
    ap.add_argument("--search-token", default=None, metavar="TOKEN",
                    help="bearer token when --search-url points at an "
                         "auth-enabled service")
    ap.add_argument("--search-timeout", type=float, default=None,
                    metavar="SECONDS",
                    help="per-request timeout against --search-url "
                         "(default: the service client's 600s)")
    ap.add_argument("--emit-traces", default=None, metavar="PATH",
                    help="append one measured source='serve' StepTrace "
                         "(JSONL, wire format) from this generate's decode "
                         "steps — the same feedback inlet launch/train.py "
                         "feeds ('python -m repro.serve.search_service "
                         "traces' or CalibrationLoop.ingest)")
    args = ap.parse_args()

    report = None
    if args.search_spec:
        try:
            spec, report = pick_strategy_from_spec(
                args.search_spec, url=args.search_url,
                token=args.search_token, timeout=args.search_timeout,
            )
        except (RuntimeError, OSError) as e:
            print(f"search service unavailable: {e}", file=sys.stderr)
            return 2
        b = report.best
        if b is None:
            print(f"search spec {args.search_spec}: no feasible strategy")
        else:
            print(f"search spec {args.search_spec} ({report.mode}): "
                  f"{b.device} x{b.num_devices} tp={b.tensor_parallel} "
                  f"pp={b.pipeline_parallel} dp={b.data_parallel} -> "
                  f"{report.best_sim.throughput_tokens:,.0f} tok/s simulated")

    arch = get_reduced(args.arch)
    cfg = lm.ModelCfg(dtype=jnp.float32, attn_impl="xla", ssm_impl="xla")
    params = lm.init_params(arch, jax.random.PRNGKey(0))
    engine = ServeEngine(arch, cfg, params,
                         max_len=args.prompt_len + args.tokens + 8)

    prompts = np.random.default_rng(0).integers(
        0, arch.vocab, size=(args.batch, args.prompt_len)
    ).astype(np.int32)

    t0 = time.time()
    result = engine.generate(prompts, max_new_tokens=args.tokens,
                             temperature=args.temperature, seed=1)
    dt = time.time() - t0
    total_new = args.batch * args.tokens
    print(f"arch={arch.name} ({arch.total_params()/1e6:.1f}M params, "
          f"family={arch.family})")
    print(f"batched generate: {args.batch} requests x {args.tokens} tokens "
          f"in {dt:.2f}s ({total_new/dt:.1f} tok/s incl. compile)")
    for i, row in enumerate(result.tokens[:2]):
        print(f"  req{i}: prompt={row[:args.prompt_len].tolist()[:8]}... "
              f"generated={row[args.prompt_len:].tolist()}")

    # drop the jit-compile warmup steps before the trace ships to the
    # calibration loop — a compile-polluted step time skews drift scoring
    # toward spurious refits; the exclusion is recorded on the trace
    clean_steps = result.step_times[result.warmup_steps:]
    if args.emit_traces and clean_steps:
        from repro.calibration.traces import StepTrace, append_trace
        from repro.core.params import ParallelStrategy

        # attribute the measurement to the searched strategy when there is
        # one; otherwise describe the device this serve actually ran on
        strategy = report.best if report is not None and report.best \
            is not None else ParallelStrategy(
                device="tpu-v5e", num_devices=max(jax.device_count(), 1),
                micro_batch_size=max(args.batch, 1),
            )
        trace = StepTrace(
            arch=arch, strategy=strategy,
            global_batch=args.batch, seq=args.prompt_len + args.tokens,
            step_times=clean_steps, source="serve",
            warmup_steps_excluded=result.warmup_steps,
        )
        append_trace(args.emit_traces, trace)
        print(f"[trace] appended {len(clean_steps)}-step serve trace "
              f"({result.warmup_steps} warmup step(s) excluded, median "
              f"{trace.measured_step_time:.4f}s) to {args.emit_traces}")
    elif args.emit_traces:
        print("[trace] nothing to append: every measured step was a "
              "compile warmup")


if __name__ == "__main__":
    sys.exit(main())
